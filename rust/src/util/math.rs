//! Vector math for routing and EAM similarity search.

/// Cosine similarity between two equal-length vectors; 0.0 if either is 0.
pub fn cosine(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let (mut dot, mut na, mut nb) = (0.0f32, 0.0f32, 0.0f32);
    for i in 0..a.len() {
        dot += a[i] * b[i];
        na += a[i] * a[i];
        nb += b[i] * b[i];
    }
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    dot / (na.sqrt() * nb.sqrt())
}

/// Cosine against a pre-normalized query (`q_norm = ||q||`), with the
/// candidate's norm supplied — the EAMC hot loop precomputes both.
#[inline]
pub fn cosine_prenorm(dot: f32, q_norm: f32, c_norm: f32) -> f32 {
    if q_norm == 0.0 || c_norm == 0.0 {
        0.0
    } else {
        dot / (q_norm * c_norm)
    }
}

/// L2 norm.
pub fn norm(a: &[f32]) -> f32 {
    a.iter().map(|x| x * x).sum::<f32>().sqrt()
}

/// Dot product.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for i in 0..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// In-place softmax.
pub fn softmax(xs: &mut [f32]) {
    if xs.is_empty() {
        return;
    }
    let m = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0;
    for x in xs.iter_mut() {
        *x = (*x - m).exp();
        sum += *x;
    }
    for x in xs.iter_mut() {
        *x /= sum;
    }
}

/// Indices of the `k` largest values, ties broken toward lower index,
/// result ordered by descending value.  O(n·k) — n is 64 here, and this
/// beats a full sort for k=6.
pub fn top_k(xs: &[f64], k: usize) -> Vec<usize> {
    let k = k.min(xs.len());
    let mut out: Vec<usize> = Vec::with_capacity(k);
    let mut taken = vec![false; xs.len()];
    for _ in 0..k {
        let mut best = usize::MAX;
        let mut best_v = f64::NEG_INFINITY;
        for (i, &v) in xs.iter().enumerate() {
            if !taken[i] && v > best_v {
                best_v = v;
                best = i;
            }
        }
        taken[best] = true;
        out.push(best);
    }
    out
}

/// Bitmask of the `k` largest values of an f32 row of length ≤ 64, ties
/// broken toward lower index — the same selection [`top_k`] makes (f32 →
/// f64 conversion is exact, so the comparisons are identical), but
/// allocation-free: the hot predictor path (`LearnedModel::top_set`)
/// calls this once per (token, layer).
pub fn top_k_mask_f32(xs: &[f32], k: usize) -> u64 {
    debug_assert!(xs.len() <= 64);
    let k = k.min(xs.len());
    let mut mask = 0u64;
    for _ in 0..k {
        let mut best = usize::MAX;
        let mut best_v = f32::NEG_INFINITY;
        for (i, &v) in xs.iter().enumerate() {
            if (mask >> i) & 1 == 0 && v > best_v {
                best_v = v;
                best = i;
            }
        }
        if best == usize::MAX {
            break; // only NaN / -inf left: nothing selectable
        }
        mask |= 1u64 << best;
    }
    mask
}

/// Normalize a vector to unit L2 norm in place (no-op on zero vectors).
pub fn normalize(xs: &mut [f32]) {
    let n = norm(xs);
    if n > 1e-12 {
        for x in xs.iter_mut() {
            *x /= n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cosine_basics() {
        assert!((cosine(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-6);
        assert!(cosine(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-6);
        assert!((cosine(&[1.0, 0.0], &[-1.0, 0.0]) + 1.0).abs() < 1e-6);
        assert_eq!(cosine(&[0.0, 0.0], &[1.0, 1.0]), 0.0);
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut v = vec![1.0f32, 2.0, 3.0, -1.0];
        softmax(&mut v);
        assert!((v.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert!(v[2] > v[1] && v[1] > v[0] && v[0] > v[3]);
    }

    #[test]
    fn softmax_handles_large_values() {
        let mut v = vec![1000.0f32, 1000.0];
        softmax(&mut v);
        assert!((v[0] - 0.5).abs() < 1e-5);
    }

    #[test]
    fn top_k_selects_and_orders() {
        let xs = [0.1, 5.0, 3.0, 4.0, 2.0];
        assert_eq!(top_k(&xs, 3), vec![1, 3, 2]);
        assert_eq!(top_k(&xs, 0), Vec::<usize>::new());
        assert_eq!(top_k(&xs, 10).len(), 5);
    }

    #[test]
    fn top_k_tie_prefers_lower_index() {
        let xs = [1.0, 1.0, 1.0];
        assert_eq!(top_k(&xs, 2), vec![0, 1]);
    }

    // seeded-random property checks (no proptest in the offline build)
    #[test]
    fn prop_top_k_matches_sort() {
        let mut rng = crate::util::Rng::new(21);
        for _ in 0..300 {
            let n = rng.range(1, 40);
            let xs: Vec<f64> = (0..n).map(|_| rng.f64() * 200.0 - 100.0).collect();
            let k = rng.range(1, 10);
            let got = top_k(&xs, k);
            let mut idx: Vec<usize> = (0..xs.len()).collect();
            idx.sort_by(|&a, &b| xs[b].partial_cmp(&xs[a]).unwrap().then(a.cmp(&b)));
            idx.truncate(k.min(xs.len()));
            assert_eq!(got, idx);
        }
    }

    /// The f32 mask selection must break ties exactly like `top_k` over
    /// the f64-widened row (the pre-refactor `top_set` path).
    #[test]
    fn prop_top_k_mask_f32_matches_f64_top_k() {
        let mut rng = crate::util::Rng::new(23);
        for _ in 0..400 {
            let n = rng.range(1, 64);
            // coarse quantization forces frequent exact ties
            let xs: Vec<f32> = (0..n)
                .map(|_| ((rng.f64() * 8.0).floor() / 4.0) as f32)
                .collect();
            let k = rng.range(1, 10);
            let mask = top_k_mask_f32(&xs, k);
            let wide: Vec<f64> = xs.iter().map(|&x| x as f64).collect();
            let mut want = 0u64;
            for i in top_k(&wide, k) {
                want |= 1u64 << i;
            }
            assert_eq!(mask, want, "xs={xs:?} k={k}");
        }
    }

    #[test]
    fn top_k_mask_f32_edge_cases() {
        assert_eq!(top_k_mask_f32(&[], 3), 0);
        assert_eq!(top_k_mask_f32(&[1.0, 2.0], 5), 0b11);
        // ties prefer lower index
        assert_eq!(top_k_mask_f32(&[1.0, 1.0, 1.0], 2), 0b011);
        // unselectable values (-inf) are skipped gracefully
        assert_eq!(top_k_mask_f32(&[f32::NEG_INFINITY, 2.0], 2), 0b10);
    }

    #[test]
    fn prop_cosine_bounded() {
        let mut rng = crate::util::Rng::new(22);
        for _ in 0..300 {
            let a: Vec<f32> = (0..8).map(|_| (rng.f64() * 20.0 - 10.0) as f32).collect();
            let b: Vec<f32> = (0..8).map(|_| (rng.f64() * 20.0 - 10.0) as f32).collect();
            let c = cosine(&a, &b);
            assert!((-1.0001..=1.0001).contains(&c));
        }
    }
}
