//! Small, allocation-free building blocks shared by every subsystem.

pub mod expert_set;
pub mod json;
pub mod math;
pub mod parallel;
pub mod rng;
pub mod stats;

pub use expert_set::{words_for, ExpertSet, ExpertSetIter, MAX_EXPERTS, N_MAX};
pub use rng::Rng;
