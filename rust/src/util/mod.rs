//! Small, allocation-free building blocks shared by every subsystem.

pub mod expert_set;
pub mod json;
pub mod math;
pub mod parallel;
pub mod rng;
pub mod stats;

pub use expert_set::ExpertSet;
pub use rng::Rng;
