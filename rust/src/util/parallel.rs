//! Scoped-thread fan-out shared by every grid harness in the crate: the
//! Fig-7 capacity sweep, the tiered surface, the workload load sweep,
//! and the corpus-level stack-distance profiler all map their jobs over
//! the same deterministic worker pool.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};

use crate::Result;

/// Worker count for the sweep harnesses: `MOEB_SWEEP_THREADS` if set
/// (>= 1), else the machine's available parallelism.  Parsed once per
/// process (`OnceLock`) — callers hit this per sweep invocation, and
/// nothing in the crate mutates the variable at runtime.
pub fn sweep_threads() -> usize {
    static THREADS: OnceLock<usize> = OnceLock::new();
    *THREADS.get_or_init(|| {
        match std::env::var("MOEB_SWEEP_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
        {
            Some(n) if n >= 1 => n,
            _ => std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
        }
    })
}

/// Map `f` over `jobs` on `threads` scoped workers.  Workers claim jobs
/// from an atomic cursor and write results back by index, so the output
/// order (and content — each job is self-contained) is identical to the
/// serial `jobs.iter().map(f)`.
pub(crate) fn parallel_map<J, R, F>(jobs: &[J], threads: usize, f: F) -> Result<Vec<R>>
where
    J: Sync,
    R: Send,
    F: Fn(&J) -> Result<R> + Sync,
{
    // a single job (or a single worker) never spawns: the scoped-thread
    // setup/teardown would cost more than it hides
    let threads = threads.max(1).min(jobs.len().max(1));
    if jobs.len() <= 1 || threads <= 1 {
        return jobs.iter().map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<Result<R>>>> = jobs.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= jobs.len() {
                    break;
                }
                let r = f(&jobs[i]);
                *slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .unwrap()
                .expect("sweep worker exited without writing its slot")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_map_matches_serial_for_any_worker_count() {
        let jobs: Vec<usize> = (0..37).collect();
        let serial = parallel_map(&jobs, 1, |&j| Ok(j * j)).unwrap();
        for threads in [2usize, 4, 16, 64] {
            let par = parallel_map(&jobs, threads, |&j| Ok(j * j)).unwrap();
            assert_eq!(serial, par, "threads={threads}");
        }
    }

    #[test]
    fn parallel_map_propagates_errors() {
        let jobs = [1usize, 2, 3];
        let r = parallel_map(&jobs, 2, |&j| {
            if j == 2 {
                anyhow::bail!("boom")
            } else {
                Ok(j)
            }
        });
        assert!(r.is_err());
    }
}
