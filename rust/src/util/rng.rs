//! Deterministic, dependency-free RNG (xoshiro256++) with the sampling
//! helpers the workload generator needs (uniform, gumbel, dirichlet-ish,
//! choice, shuffle).
//!
//! The Rust side does NOT have to be bit-identical with numpy's streams —
//! fidelity tests are statistical — but runs must be reproducible from a
//! seed across platforms, which xoshiro256++ guarantees.

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 expansion (never all-zero state).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self {
            s: [next(), next(), next(), next()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift; bias negligible for our n << 2^64
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi) — matches numpy's `integers(lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard Gumbel(0,1) sample.
    #[inline]
    pub fn gumbel(&mut self) -> f64 {
        let u = self.f64().max(1e-300);
        -(-u.ln()).ln()
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Symmetric Dirichlet(alpha) of dimension `n` (via gamma sampling,
    /// Marsaglia-Tsang; alpha < 1 handled with the boost trick).
    pub fn dirichlet(&mut self, alpha: f64, n: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..n).map(|_| self.gamma(alpha)).collect();
        let s: f64 = g.iter().sum();
        if s <= 0.0 {
            return vec![1.0 / n as f64; n];
        }
        for x in &mut g {
            *x /= s;
        }
        g
    }

    fn gamma(&mut self, alpha: f64) -> f64 {
        if alpha < 1.0 {
            let u = self.f64().max(1e-300);
            return self.gamma(alpha + 1.0) * u.powf(1.0 / alpha);
        }
        let d = alpha - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4) || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Weighted choice by (unnormalized) non-negative weights.
    pub fn choose_weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut r = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            r -= w;
            if r <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_and_below_bounds() {
        let mut r = Rng::new(1);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn gumbel_mean_is_euler_gamma() {
        let mut r = Rng::new(2);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.gumbel()).sum::<f64>() / n as f64;
        assert!((mean - 0.5772).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02);
        assert!((var - 1.0).abs() < 0.03);
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(4);
        for alpha in [0.1, 1.0, 5.0] {
            let d = r.dirichlet(alpha, 10);
            assert!((d.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            assert!(d.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_choice_respects_weights() {
        let mut r = Rng::new(6);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.choose_weighted(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        let frac = counts[2] as f64 / 30_000.0;
        assert!((frac - 0.7).abs() < 0.03);
    }
}
