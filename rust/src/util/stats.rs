//! Streaming statistics helpers for metrics and benches.

/// Online mean/min/max/count accumulator (Welford variance).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
}

/// Shannon entropy (nats) of a count histogram.
pub fn entropy(counts: &[u64]) -> f64 {
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let t = total as f64;
    counts
        .iter()
        .filter(|&&c| c > 0)
        .map(|&c| {
            let p = c as f64 / t;
            -p * p.ln()
        })
        .sum()
}

/// Exact percentile (nearest-rank) of an unsorted slice.  This is the
/// reference implementation the bounded-memory `obs::hist` quantiles
/// are cross-checked against (same rank convention); NaN inputs sort
/// last (total order) instead of panicking, so a poisoned sample set
/// surfaces as NaN rather than aborting the run.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_moments() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.var() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.var(), 0.0);
    }

    #[test]
    fn entropy_uniform_vs_point() {
        let uni = entropy(&[10, 10, 10, 10]);
        assert!((uni - (4.0f64).ln()).abs() < 1e-9);
        assert_eq!(entropy(&[40, 0, 0, 0]), 0.0);
        assert_eq!(entropy(&[]), 0.0);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        assert_eq!(percentile(&xs, 50.0), 51.0); // nearest-rank rounds up at .5
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
