//! Multi-tenant workload simulator: open-loop arrivals, shared-cache
//! contention, SLO metrics.
//!
//! The paper (and the Fig-7 sweep) replays one stream at a time; the
//! ROADMAP's north star is heavy multi-user traffic, where concurrent
//! decode streams interleave on one engine and compete for one expert
//! cache — the regime where recency heuristics lose their locality and
//! a real predictor has to earn its keep.  This module makes that
//! regime measurable, deterministically:
//!
//! * [`profile`] — who sends traffic: tenant profiles (Poisson / bursty
//!   on-off arrival processes, prompt/decode length distributions,
//!   per-tenant trace corpora) materialized into a seeded, open-loop
//!   arrival [`Schedule`].
//! * [`sched`] — the virtual-time engine: one shared
//!   [`crate::memory::ExpertMemory`] (flat or tiered), pluggable
//!   scheduling policies (FCFS / round-robin / shortest-remaining-
//!   decode), a FIFO admission queue with modeled queueing delay, and
//!   invariant counters (work conservation, starvation) the tests and
//!   the CI perf gate assert on.
//! * [`slo`] — per-tenant and aggregate TTFT / TBT / request-latency
//!   percentiles, hit-rate-under-contention, and the deterministic JSON
//!   encoding behind `benches/golden/workload.json`.
//! * [`sweep_load`] — offered load × cache fraction × predictor (×
//!   policy × backend) grids that extend Fig 7 into throughput–latency
//!   curves, fanned out over the Fig-7 sweep's worker threads.
//!
//! Everything is virtual-time and seed-deterministic: no wall clock, no
//! artifacts, no PJRT — `cargo bench --bench workload_contention` and
//! the `serve-sim` CLI subcommand run self-contained.
//!
//! # Example
//!
//! Materialize a deterministic two-tenant arrival schedule (the engine
//! entry point is [`run_workload`]; `examples/multi_tenant.rs` walks
//! the whole pipeline from spec to SLO report):
//!
//! ```
//! use moe_beyond::workload::{synthetic_pools, WorkloadSpec};
//!
//! let spec = WorkloadSpec::example(2, 7, 4.0);
//! let pools = synthetic_pools(&spec, 6, 4, 64);
//! let schedule = spec.generate(&pools).unwrap();
//! assert!(!schedule.arrivals.is_empty());
//! // same seed, same pools ⇒ the same schedule, event for event
//! assert_eq!(
//!     schedule.arrivals.len(),
//!     spec.generate(&pools).unwrap().arrivals.len()
//! );
//! ```

pub mod profile;
pub mod sched;
mod sched_queue;
pub mod slo;
pub mod sweep_load;

pub use profile::{
    synthetic_fit_pool, synthetic_pool, synthetic_pools, ArrivalEvent, ArrivalProcess, Schedule,
    TenantProfile, WorkloadSpec,
};
pub use sched::{
    inflight_state_bytes_per_stream, run_workload, run_workload_compiled, run_workload_engine,
    run_workload_obs, run_workload_sharded, MemoryBuilder, SchedCounters, SchedEngine, SchedPolicy,
    WorkloadInputs,
};
pub use slo::{report_json, TenantSlo, WorkloadReport};
pub use sweep_load::{
    load_csv, run_point_obs, sweep_load, sweep_load_threaded, Backend, LoadPoint, LoadSweepInputs,
};
