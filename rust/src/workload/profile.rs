//! Tenant profiles and open-loop arrival generation.
//!
//! A [`WorkloadSpec`] describes *who* sends traffic (a set of
//! [`TenantProfile`]s, each with its own arrival process and
//! prompt/decode length distributions) independently of *how fast the
//! engine drains it* — arrivals are open-loop: a tenant does not wait
//! for its previous request to complete before sending the next one,
//! which is what makes overload and queueing delay observable at all
//! (the closed "submit everything up front" pattern can never show
//! them).
//!
//! Everything is derived deterministically from `WorkloadSpec::seed`:
//! the same spec always yields byte-identical arrival schedules, which
//! is what the CI perf gate keys on.

use anyhow::ensure;

use crate::trace::PromptTrace;
use crate::util::Rng;
use crate::Result;

/// Open-loop arrival process for one tenant.
#[derive(Debug, Clone)]
pub enum ArrivalProcess {
    /// Memoryless arrivals at `rate_rps` requests/second.
    Poisson { rate_rps: f64 },
    /// On/off-modulated Poisson: arrivals at `rate_rps` during `on_secs`
    /// windows, silence for `off_secs` between them (bursty tenants —
    /// agents, cron jobs — whose bursts are what break steady-state
    /// cache locality).
    Bursty {
        rate_rps: f64,
        on_secs: f64,
        off_secs: f64,
    },
}

impl ArrivalProcess {
    /// Mean offered rate in requests/second (burst rate × duty cycle).
    pub fn mean_rps(&self) -> f64 {
        match self {
            ArrivalProcess::Poisson { rate_rps } => *rate_rps,
            ArrivalProcess::Bursty {
                rate_rps,
                on_secs,
                off_secs,
            } => rate_rps * on_secs / (on_secs + off_secs),
        }
    }

    /// Same process shape with every rate scaled by `mult` (the offered
    /// load axis of `sweep_load`).
    pub fn scaled(&self, mult: f64) -> Self {
        match self {
            ArrivalProcess::Poisson { rate_rps } => ArrivalProcess::Poisson {
                rate_rps: rate_rps * mult,
            },
            ArrivalProcess::Bursty {
                rate_rps,
                on_secs,
                off_secs,
            } => ArrivalProcess::Bursty {
                rate_rps: rate_rps * mult,
                on_secs: *on_secs,
                off_secs: *off_secs,
            },
        }
    }

    pub fn validate(&self) -> Result<()> {
        match self {
            ArrivalProcess::Poisson { rate_rps } => {
                ensure!(*rate_rps > 0.0, "poisson rate must be > 0");
            }
            ArrivalProcess::Bursty {
                rate_rps,
                on_secs,
                off_secs,
            } => {
                ensure!(*rate_rps > 0.0, "burst rate must be > 0");
                ensure!(*on_secs > 0.0, "burst on-window must be > 0");
                ensure!(*off_secs >= 0.0, "negative burst off-window");
            }
        }
        Ok(())
    }
}

/// One traffic class: arrival process plus request-shape distributions.
#[derive(Debug, Clone)]
pub struct TenantProfile {
    pub name: String,
    pub arrival: ArrivalProcess,
    /// Prompt length drawn uniformly from this inclusive range.
    pub prompt_tokens: (usize, usize),
    /// `max_new_tokens` drawn uniformly from this inclusive range.
    pub decode_tokens: (usize, usize),
    /// Seeds this tenant's trace corpus (synthetic pool or
    /// `trace::corpus` sampler) so tenants have distinct localities.
    pub trace_seed: u64,
}

impl TenantProfile {
    pub fn validate(&self) -> Result<()> {
        ensure!(!self.name.is_empty(), "tenant needs a name");
        self.arrival.validate()?;
        ensure!(
            self.prompt_tokens.0 >= 1 && self.prompt_tokens.0 <= self.prompt_tokens.1,
            "tenant {}: bad prompt_tokens range",
            self.name
        );
        ensure!(
            self.decode_tokens.0 >= 1 && self.decode_tokens.0 <= self.decode_tokens.1,
            "tenant {}: bad decode_tokens range",
            self.name
        );
        Ok(())
    }
}

/// A full multi-tenant workload description.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    /// Master seed: arrival times, request shapes and trace choices all
    /// derive from it.
    pub seed: u64,
    /// Arrivals are generated inside `[0, horizon_secs)`; the simulator
    /// then drains the backlog past the horizon.
    pub horizon_secs: f64,
    pub tenants: Vec<TenantProfile>,
}

impl WorkloadSpec {
    pub fn validate(&self) -> Result<()> {
        ensure!(self.horizon_secs > 0.0, "horizon must be > 0");
        ensure!(!self.tenants.is_empty(), "spec needs at least one tenant");
        for t in &self.tenants {
            t.validate()?;
        }
        Ok(())
    }

    /// The spec with every tenant's arrival rate scaled by `mult`.
    pub fn with_load(&self, mult: f64) -> WorkloadSpec {
        let mut s = self.clone();
        for t in &mut s.tenants {
            t.arrival = t.arrival.scaled(mult);
        }
        s
    }

    /// Mean offered load across all tenants (requests/second).
    pub fn offered_rps(&self) -> f64 {
        self.tenants.iter().map(|t| t.arrival.mean_rps()).sum()
    }

    /// A deterministic n-tenant default mix cycling through three
    /// archetypes — interactive chat (short, steady), an agent
    /// (medium, bursty) and batch summarization (long prompts, slow) —
    /// shared by the CLI, the bench, the example and the tests so they
    /// all exercise the same traffic shape.
    pub fn example(n_tenants: usize, seed: u64, horizon_secs: f64) -> WorkloadSpec {
        let archetypes: [(&str, ArrivalProcess, (usize, usize), (usize, usize)); 3] = [
            (
                "chat",
                ArrivalProcess::Poisson { rate_rps: 0.5 },
                (24, 48),
                (8, 16),
            ),
            (
                "agent",
                ArrivalProcess::Bursty {
                    rate_rps: 1.0,
                    on_secs: 2.0,
                    off_secs: 2.0,
                },
                (32, 64),
                (12, 24),
            ),
            (
                "batch",
                ArrivalProcess::Poisson { rate_rps: 0.2 },
                (64, 96),
                (16, 32),
            ),
        ];
        let tenants = (0..n_tenants.max(1))
            .map(|i| {
                let (name, arrival, prompt, decode) = archetypes[i % 3].clone();
                TenantProfile {
                    name: format!("{}-{}", name, i),
                    arrival,
                    prompt_tokens: prompt,
                    decode_tokens: decode,
                    trace_seed: seed
                        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                        .wrapping_add(i as u64 + 1),
                }
            })
            .collect();
        WorkloadSpec {
            seed,
            horizon_secs,
            tenants,
        }
    }
}

/// One generated request arrival (times in virtual µs from run start).
#[derive(Debug, Clone)]
pub struct ArrivalEvent {
    pub arrival_us: f64,
    /// Index into `WorkloadSpec::tenants` / the trace pools.
    pub tenant: usize,
    /// Global id, assigned in arrival order after the tenant merge.
    pub request_id: u64,
    /// Index into the tenant's trace pool.
    pub trace_idx: usize,
    /// Prefill length (clamped so at least one decode token remains).
    pub prompt_tokens: usize,
    /// Decode length (clamped to the trace's remaining tokens).
    pub decode_tokens: usize,
}

/// A fully materialized arrival schedule (sorted by arrival time).
#[derive(Debug, Clone)]
pub struct Schedule {
    pub arrivals: Vec<ArrivalEvent>,
    pub horizon_us: f64,
    /// Realized offered load: arrivals / horizon.
    pub offered_rps: f64,
}

impl WorkloadSpec {
    /// Materialize the arrival schedule against per-tenant trace pools
    /// (`pools[t]` backs tenant `t`; request lengths are clamped to the
    /// chosen trace so every request has ≥ 1 prompt and ≥ 1 decode
    /// token).  Deterministic in the spec seed.
    pub fn generate(&self, pools: &[Vec<PromptTrace>]) -> Result<Schedule> {
        self.validate()?;
        ensure!(
            pools.len() == self.tenants.len(),
            "need one trace pool per tenant ({} pools for {} tenants)",
            pools.len(),
            self.tenants.len()
        );
        for (i, p) in pools.iter().enumerate() {
            ensure!(!p.is_empty(), "tenant {} has an empty trace pool", i);
            for tr in p {
                ensure!(
                    tr.n_tokens() >= 2,
                    "tenant {} has a trace shorter than 2 tokens",
                    i
                );
            }
        }

        let horizon_us = self.horizon_secs * 1e6;
        let mut arrivals: Vec<ArrivalEvent> = Vec::new();
        for (ti, tenant) in self.tenants.iter().enumerate() {
            let mut rng = Rng::new(
                self.seed
                    .wrapping_mul(0x2545_F491_4F6C_DD1D)
                    .wrapping_add((ti as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15))
                    ^ tenant.trace_seed,
            );
            for t_us in arrival_times(&tenant.arrival, horizon_us, &mut rng) {
                let trace_idx = rng.below(pools[ti].len());
                let n = pools[ti][trace_idx].n_tokens();
                let want_prompt = rng.range(tenant.prompt_tokens.0, tenant.prompt_tokens.1 + 1);
                let want_decode = rng.range(tenant.decode_tokens.0, tenant.decode_tokens.1 + 1);
                let prompt_tokens = want_prompt.clamp(1, n - 1);
                let decode_tokens = want_decode.clamp(1, n - prompt_tokens);
                arrivals.push(ArrivalEvent {
                    arrival_us: t_us,
                    tenant: ti,
                    request_id: 0, // assigned after the merge
                    trace_idx,
                    prompt_tokens,
                    decode_tokens,
                });
            }
        }
        // stable merge: arrival time, ties broken by tenant index so the
        // schedule is identical regardless of float coincidences
        arrivals.sort_by(|a, b| {
            a.arrival_us
                .partial_cmp(&b.arrival_us)
                .unwrap()
                .then(a.tenant.cmp(&b.tenant))
        });
        for (i, ev) in arrivals.iter_mut().enumerate() {
            ev.request_id = i as u64;
        }
        let offered_rps = arrivals.len() as f64 / self.horizon_secs;
        Ok(Schedule {
            arrivals,
            horizon_us,
            offered_rps,
        })
    }
}

/// Sample one tenant's arrival times (µs) over `[0, horizon_us)`.
fn arrival_times(process: &ArrivalProcess, horizon_us: f64, rng: &mut Rng) -> Vec<f64> {
    let mut out = Vec::new();
    match *process {
        ArrivalProcess::Poisson { rate_rps } => {
            let mut clock = 0.0;
            loop {
                clock += exp_us(rate_rps, rng);
                if clock >= horizon_us {
                    break;
                }
                out.push(clock);
            }
        }
        ArrivalProcess::Bursty {
            rate_rps,
            on_secs,
            off_secs,
        } => {
            // exact on/off modulation: draw exponential inter-arrivals in
            // "on-time" coordinates, then map on-time to wall time by
            // inserting the off windows between bursts
            let on_us = on_secs * 1e6;
            let period_us = (on_secs + off_secs) * 1e6;
            let mut on_time = 0.0;
            loop {
                on_time += exp_us(rate_rps, rng);
                let cycles = (on_time / on_us).floor();
                let wall = cycles * period_us + (on_time - cycles * on_us);
                if wall >= horizon_us {
                    break;
                }
                out.push(wall);
            }
        }
    }
    out
}

/// Exponential inter-arrival sample in µs for a rate in requests/second.
fn exp_us(rate_rps: f64, rng: &mut Rng) -> f64 {
    let u = (1.0 - rng.f64()).max(1e-300);
    -u.ln() / rate_rps * 1e6
}

/// Reuse-heavy synthetic trace pool for one tenant: every prompt draws
/// its experts from a ~10-wide working set inside the tenant's own
/// 24-expert band, so concurrent tenants genuinely compete for cache
/// instead of sharing one global working set.  Library twin of the
/// bench-side `mk_reuse_traces`, kept here so the CLI, bench, example
/// and tests cannot drift apart.
pub fn synthetic_pool(
    tenant_seed: u64,
    n_traces: usize,
    n_tokens: usize,
    n_layers: u16,
    n_experts: usize,
) -> Vec<PromptTrace> {
    assert!(
        (24..=crate::util::MAX_EXPERTS).contains(&n_experts),
        "synthetic pool needs 24..={} experts",
        crate::util::MAX_EXPERTS
    );
    let mut rng = Rng::new(tenant_seed);
    let band_start = rng.below((n_experts - 24).max(1)) as u8;
    (0..n_traces)
        .map(|i| {
            let base = band_start + rng.below(24 - 10) as u8;
            let mut experts = Vec::with_capacity(n_tokens * n_layers as usize * 2);
            for _ in 0..n_tokens * n_layers as usize {
                let a = base + rng.below(10) as u8;
                let mut b = base + rng.below(10) as u8;
                if b == a {
                    b = base + ((a - base + 1) % 10);
                }
                experts.push(a);
                experts.push(b);
            }
            PromptTrace {
                prompt_id: i as u32,
                n_layers,
                top_k: 2,
                d_emb: 0,
                tokens: vec![0; n_tokens],
                embeddings: vec![],
                experts,
            }
        })
        .collect()
}

/// One synthetic pool per tenant of `spec`, each long enough for the
/// tenant's largest prompt + decode draw.
pub fn synthetic_pools(
    spec: &WorkloadSpec,
    n_traces: usize,
    n_layers: u16,
    n_experts: usize,
) -> Vec<Vec<PromptTrace>> {
    spec.tenants
        .iter()
        .map(|t| {
            let n_tokens = t.prompt_tokens.1 + t.decode_tokens.1;
            synthetic_pool(t.trace_seed, n_traces, n_tokens, n_layers, n_experts)
        })
        .collect()
}

/// Flattened fit corpus for offline-fitted predictors (EAMC,
/// popularity): the same per-tenant generator at a fixed seed offset,
/// so fit traces resemble — but never duplicate — each tenant's serving
/// pool.  The one definition of that offset, shared by the CLI, bench,
/// example and tests.
pub fn synthetic_fit_pool(
    spec: &WorkloadSpec,
    n_traces: usize,
    n_layers: u16,
    n_experts: usize,
) -> Vec<PromptTrace> {
    let mut fit_spec = spec.clone();
    for t in &mut fit_spec.tenants {
        t.trace_seed = t.trace_seed.wrapping_add(0xF17);
    }
    synthetic_pools(&fit_spec, n_traces, n_layers, n_experts).concat()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> WorkloadSpec {
        WorkloadSpec::example(3, 7, 10.0)
    }

    /// Wide worlds (> 64 experts) generate in-range ids, and across a
    /// handful of tenant seeds the bands genuinely reach past the
    /// single-word id space.
    #[test]
    fn wide_pool_spans_beyond_one_word() {
        let mut max_id = 0u8;
        for seed in 0..20u64 {
            for tr in synthetic_pool(seed, 3, 16, 2, 160) {
                for &e in &tr.experts {
                    assert!((e as usize) < 160, "id {e} out of range");
                    max_id = max_id.max(e);
                }
            }
        }
        assert!(max_id >= 64, "expected some band above expert 63, max {max_id}");
    }

    #[test]
    fn example_spec_validates_and_mixes_archetypes() {
        let s = spec();
        s.validate().unwrap();
        assert_eq!(s.tenants.len(), 3);
        assert!(matches!(s.tenants[1].arrival, ArrivalProcess::Bursty { .. }));
        assert!(s.offered_rps() > 0.0);
    }

    #[test]
    fn load_scaling_scales_rates_only() {
        let s = spec();
        let s2 = s.with_load(4.0);
        assert!((s2.offered_rps() - 4.0 * s.offered_rps()).abs() < 1e-9);
        assert_eq!(s2.tenants[0].prompt_tokens, s.tenants[0].prompt_tokens);
    }

    #[test]
    fn generate_is_deterministic_and_sorted() {
        let s = spec();
        let pools = synthetic_pools(&s, 6, 4, 64);
        let a = s.generate(&pools).unwrap();
        let b = s.generate(&pools).unwrap();
        assert!(!a.arrivals.is_empty());
        assert_eq!(a.arrivals.len(), b.arrivals.len());
        for (x, y) in a.arrivals.iter().zip(b.arrivals.iter()) {
            assert_eq!(x.arrival_us.to_bits(), y.arrival_us.to_bits());
            assert_eq!(x.tenant, y.tenant);
            assert_eq!(x.trace_idx, y.trace_idx);
            assert_eq!(x.prompt_tokens, y.prompt_tokens);
            assert_eq!(x.decode_tokens, y.decode_tokens);
        }
        for w in a.arrivals.windows(2) {
            assert!(w[0].arrival_us <= w[1].arrival_us);
        }
        for (i, ev) in a.arrivals.iter().enumerate() {
            assert_eq!(ev.request_id, i as u64);
            assert!(ev.arrival_us < s.horizon_secs * 1e6);
            let tr = &pools[ev.tenant][ev.trace_idx];
            assert!(ev.prompt_tokens >= 1 && ev.decode_tokens >= 1);
            assert!(ev.prompt_tokens + ev.decode_tokens <= tr.n_tokens());
        }
    }

    #[test]
    fn different_seed_changes_the_schedule() {
        let s1 = spec();
        let mut s2 = spec();
        s2.seed = 8;
        s2.tenants = WorkloadSpec::example(3, 8, 10.0).tenants;
        let pools = synthetic_pools(&s1, 6, 4, 64);
        let a = s1.generate(&pools).unwrap();
        let b = s2.generate(&pools).unwrap();
        let same = a.arrivals.len() == b.arrivals.len()
            && a.arrivals
                .iter()
                .zip(b.arrivals.iter())
                .all(|(x, y)| x.arrival_us.to_bits() == y.arrival_us.to_bits());
        assert!(!same, "seed change left the schedule identical");
    }

    #[test]
    fn bursty_arrivals_stay_inside_on_windows() {
        let s = WorkloadSpec {
            seed: 3,
            horizon_secs: 40.0,
            tenants: vec![TenantProfile {
                name: "burst".into(),
                arrival: ArrivalProcess::Bursty {
                    rate_rps: 2.0,
                    on_secs: 1.0,
                    off_secs: 3.0,
                },
                prompt_tokens: (4, 8),
                decode_tokens: (2, 4),
                trace_seed: 9,
            }],
        };
        let pools = synthetic_pools(&s, 4, 2, 64);
        let sched = s.generate(&pools).unwrap();
        assert!(sched.arrivals.len() >= 4, "burst tenant produced too few arrivals");
        let period = 4.0 * 1e6;
        let on = 1.0 * 1e6;
        for ev in &sched.arrivals {
            let pos = ev.arrival_us % period;
            assert!(pos < on + 1e-3, "arrival at {} lands in the off window", ev.arrival_us);
        }
    }

    #[test]
    fn synthetic_pool_shapes() {
        let p = synthetic_pool(5, 4, 30, 3, 64);
        assert_eq!(p.len(), 4);
        for tr in &p {
            assert_eq!(tr.n_tokens(), 30);
            assert_eq!(tr.experts.len(), 30 * 3 * 2);
            assert!(tr.experts.iter().all(|&e| e < 64));
        }
    }
}
