//! Virtual-time multi-tenant scheduler: interleaves the decode steps of
//! every in-flight stream against ONE shared [`ExpertMemory`].
//!
//! The engine model matches the serving coordinator's reality (one edge
//! accelerator == one execution stream): at any instant exactly one
//! stream is either prefilling or decoding one token, and every stream's
//! lookups/prefetches hit the same residency backend — so streams evict
//! each other's experts, which is precisely the contention regime the
//! single-stream Fig-7 replay cannot show.
//!
//! Time is virtual (µs): a decode step occupies the engine for
//! `token_compute_us` plus the memory model's demand+stall delta for
//! that token; prefill occupies `prefill_us_per_token × prompt` plus its
//! fetch traffic.  No wall clock is ever read, so a seeded workload
//! replays byte-identically — the CI perf gate depends on this.
//!
//! # Scale
//!
//! The drain sustains 10⁵–10⁶ concurrent streams: in-flight state lives
//! in flat SoA columns (`SoaStreams`) indexed by a stable slot, the
//! runnable set is an O(1)-amortized bucket/ring index
//! (`workload/sched_queue.rs`), per-slot predictors are built
//! lazily on first use, and everything scales with the concurrency
//! high-water mark rather than the configured limit
//! ([`inflight_state_bytes_per_stream`] states the per-stream budget,
//! gated ≤ 128 B by `benches/workload_scale.rs`).  The original
//! linear-scan algorithm is retained verbatim behind
//! [`SchedEngine::LinearScan`] and the parity suite in
//! `tests/workload_determinism.rs` pins the indexed engine byte-identical
//! to it on all three policies.  [`run_workload_sharded`] partitions
//! tenants across replica engines drained in parallel and merges the
//! accumulators in deterministic shard-index order.

use std::sync::Arc;

use crate::config::{EamConfig, SimConfig, WorkloadConfig};
use crate::memory::{ExpertMemory, MemoryStats};
use crate::metrics::Counter;
use crate::obs::{AtomicHist, ObsSink, TraceEvent};
use crate::predictor::{
    factory, CachedPredictor, DecodeContext, ExpertPredictor, PredictorKind, PredictorParams,
    TracePredictions,
};
use crate::trace::{CompiledCorpus, PromptTrace};
use crate::util::parallel::parallel_map;
use crate::util::ExpertSet;
use crate::workload::profile::{Schedule, WorkloadSpec};
use crate::workload::sched_queue::{IndexedRunnable, ReferenceRunnable, RunnableSet, StepOutcome};
use crate::workload::slo::{TenantAcc, WorkloadReport};
use crate::Result;

/// Per-tenant registry handles, grabbed once at drain start when an
/// active [`ObsSink`] is attached — the drain loop then records through
/// lock-free atomics only.
struct TenantObsHandles {
    ttft: Arc<AtomicHist>,
    tbt: Arc<AtomicHist>,
    latency: Arc<AtomicHist>,
    queue: Arc<AtomicHist>,
    tokens: Arc<Counter>,
    completions: Arc<Counter>,
    cache_hits: Arc<Counter>,
    cache_misses: Arc<Counter>,
}

/// Which in-flight stream decodes the next token.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedPolicy {
    /// Run the earliest-admitted stream to completion (no interleaving —
    /// the per-stream-locality-preserving baseline).
    Fcfs,
    /// One token per stream, cycling in admission order.
    RoundRobin,
    /// Step the stream with the fewest remaining decode tokens
    /// (shortest-remaining-decode; ties broken by admission order).
    ShortestRemaining,
}

impl SchedPolicy {
    pub const ALL: [SchedPolicy; 3] = [
        SchedPolicy::Fcfs,
        SchedPolicy::RoundRobin,
        SchedPolicy::ShortestRemaining,
    ];

    /// Config identifier (accepted by [`WorkloadConfig::policy`]).
    pub fn id(&self) -> &'static str {
        match self {
            SchedPolicy::Fcfs => "fcfs",
            SchedPolicy::RoundRobin => "round-robin",
            SchedPolicy::ShortestRemaining => "srd",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "fcfs" => Some(SchedPolicy::Fcfs),
            "round-robin" | "rr" => Some(SchedPolicy::RoundRobin),
            "srd" | "shortest-remaining" | "shortest-remaining-decode" => {
                Some(SchedPolicy::ShortestRemaining)
            }
            _ => None,
        }
    }
}

/// Which runnable-set implementation drives the drain loop.  Both
/// produce byte-identical reports (pinned by the scale-parity suite);
/// the indexed engine is the production path, the linear scan is the
/// O(n²)-at-scale reference it is verified against.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SchedEngine {
    /// O(1)-amortized pick/admit/complete via the
    /// `workload/sched_queue.rs` structures (min-index free-slot
    /// bitmap, admission ring, remaining-decode bucket queue).
    #[default]
    Indexed,
    /// The original linear scans (`position(|b| !*b)` slot search,
    /// whole-vector shortest-remaining scan, `Vec::remove` completion),
    /// retained verbatim as the parity reference.
    LinearScan,
}

/// Scheduler invariant counters — deterministic integers the perf gate
/// and the invariant tests key on.
#[derive(Debug, Clone, Default)]
pub struct SchedCounters {
    /// Decode steps executed (one token each).
    pub steps: u64,
    /// Prefill steps executed (one whole prompt each).
    pub prefill_steps: u64,
    pub admissions: u64,
    pub completions: u64,
    pub max_inflight: usize,
    /// Largest number of arrived-but-unadmitted requests observed,
    /// sampled after arrivals become due and BEFORE admission drains
    /// them — so a burst that admits within one loop iteration still
    /// reports its true backlog.
    pub max_queue_depth: usize,
    /// Virtual µs the engine spent executing.
    pub busy_us: f64,
    /// Virtual µs the engine sat idle waiting for the next arrival.
    pub idle_us: f64,
    /// Work-conservation violations: the engine idled while a runnable
    /// stream or a due arrival existed.  Must stay 0.
    pub idle_while_runnable: u64,
    /// Picks of the same stream as the previous step while another
    /// runnable stream existed.  Always 0 under round-robin (the
    /// no-starvation guarantee); positive by design under FCFS.
    pub repeat_pick_with_waiters: u64,
    /// Completions whose request id undercut an earlier-completed id —
    /// the O(1) streaming replacement for checking the full (now
    /// capped) `completion_ids` log for FCFS arrival-order drains.
    /// Exact at any scale; not part of the report's JSON encoding.
    pub out_of_order_completions: u64,
}

/// Everything one simulator run reads.
///
/// Generic over the [`ExpertSet`] word width `N` (default 1 = up to 64
/// experts); wide worlds carry their width through the learned
/// predictions and the compiled pools into the drain loop below.
pub struct WorkloadInputs<'a, const N: usize = 1> {
    pub spec: &'a WorkloadSpec,
    pub schedule: &'a Schedule,
    /// `pools[t]` backs tenant `t`'s requests.
    pub pools: &'a [Vec<PromptTrace>],
    /// Training traces for offline-fitted predictors (EAMC, popularity).
    pub fit_traces: &'a [PromptTrace],
    /// Precomputed learned predictions, `learned[t][i]` parallel to
    /// `pools[t][i]` (required iff the run uses
    /// [`PredictorKind::Learned`]; each admitted request replays its
    /// trace's predictions through a [`CachedPredictor`], exactly as the
    /// Fig-7 sweep does).
    pub learned: Option<&'a [Vec<TracePredictions<N>>]>,
    pub cfg: &'a WorkloadConfig,
    pub sim: &'a SimConfig,
    pub eam: &'a EamConfig,
    pub n_layers: usize,
    pub n_experts: usize,
}

/// Flat structure-of-arrays in-flight stream state, indexed by the
/// stable slot the runnable structures hand out.  Columns grow to the
/// concurrency high-water mark and are reused across the requests a
/// slot serves — no per-stream allocation, ever.
#[derive(Debug, Default)]
struct SoaStreams {
    tenant: Vec<u32>,
    request_id: Vec<u64>,
    trace_idx: Vec<u32>,
    prompt: Vec<u32>,
    decode: Vec<u32>,
    decoded: Vec<u32>,
    arrival_us: Vec<f64>,
    last_token_us: Vec<f64>,
    prefilled: Vec<bool>,
}

impl SoaStreams {
    fn ensure(&mut self, slot: usize) {
        if self.tenant.len() <= slot {
            let n = slot + 1;
            self.tenant.resize(n, 0);
            self.request_id.resize(n, 0);
            self.trace_idx.resize(n, 0);
            self.prompt.resize(n, 0);
            self.decode.resize(n, 0);
            self.decoded.resize(n, 0);
            self.arrival_us.resize(n, 0.0);
            self.last_token_us.resize(n, 0.0);
            self.prefilled.resize(n, false);
        }
    }
}

/// Bytes of per-stream in-flight scheduler state: the analytic sum of
/// one slot's share of every SoA column, queue link, and lazy-predictor
/// handle (predictor *internals* are shared per slot and bounded by the
/// predictor kind, not the stream count).  `benches/workload_scale.rs`
/// gates this against the 128-byte scale budget.
pub fn inflight_state_bytes_per_stream() -> usize {
    use std::mem::size_of;
    // SoaStreams: tenant/trace_idx/prompt/decode/decoded + request_id
    // + arrival_us/last_token_us + prefilled
    let soa = 5 * size_of::<u32>() + size_of::<u64>() + 2 * size_of::<f64>() + size_of::<bool>();
    let ring = 2 * size_of::<u32>(); // AdmitRing prev/next links
    let bucket = size_of::<u32>(); // RemainingBuckets intra-bucket link
    let bitmap = 1; // FreeSlots hierarchical bitmap: ~1.02 bits/slot
    let predictor = size_of::<Option<Box<dyn ExpertPredictor<1>>>>();
    soa + ring + bucket + bitmap + predictor
}

/// Run one multi-tenant workload to drain against `memory`.
///
/// Per decode token the engine mirrors `SimEngine::run_prompt`'s
/// measured phase (ONE `predict_layers` call for the whole token, then
/// per layer prefetch → lookup ground truth → end_layer → observe);
/// prefill mirrors the serving engine's warm-up (residency moves,
/// hit/miss counters stay decode-only, fetch traffic still costs
/// virtual time).  Predictor state lives in one replica per concurrency
/// slot, so a slot's EAMC grows across the requests it serves exactly
/// as a serial engine's would; `PredictorKind::Learned` instead replays
/// each request's precomputed [`TracePredictions`]
/// (`WorkloadInputs::learned`) through a per-request [`CachedPredictor`].
pub fn run_workload<const N: usize>(
    inp: &WorkloadInputs<'_, N>,
    kind: PredictorKind,
    memory: Box<dyn ExpertMemory<N>>,
) -> Result<WorkloadReport> {
    // compile each tenant pool once; requests replay pool traces many
    // times over, and `sweep_load` shares one compilation for the whole
    // grid via `run_workload_compiled`
    let compiled: Vec<CompiledCorpus<N>> =
        inp.pools.iter().map(|p| CompiledCorpus::compile(p)).collect();
    run_workload_compiled(inp, kind, memory, &compiled)
}

/// [`run_workload`] over pre-compiled tenant pools (index-parallel to
/// `inp.pools`); the load-sweep grid compiles once and every worker
/// shares the `Arc`-backed tables.
pub fn run_workload_compiled<'a, const N: usize>(
    inp: &WorkloadInputs<'a, N>,
    kind: PredictorKind,
    memory: Box<dyn ExpertMemory<N>>,
    compiled_pools: &[CompiledCorpus<N>],
) -> Result<WorkloadReport> {
    run_workload_obs(inp, kind, memory, compiled_pools, &ObsSink::default())
}

/// [`run_workload_compiled`] with an observability sink attached: the
/// drain stamps the sink's virtual clock in lock-step with the
/// scheduler clock, emits request/decode trace events, and mirrors the
/// per-tenant SLO accumulators into labeled registry metrics.  With the
/// default (no-op) sink this is exactly `run_workload_compiled` — the
/// report is byte-identical either way, because tracing never touches
/// the virtual-time arithmetic.
pub fn run_workload_obs<'a, const N: usize>(
    inp: &WorkloadInputs<'a, N>,
    kind: PredictorKind,
    memory: Box<dyn ExpertMemory<N>>,
    compiled_pools: &[CompiledCorpus<N>],
    obs: &ObsSink,
) -> Result<WorkloadReport> {
    run_workload_engine(inp, kind, memory, compiled_pools, obs, SchedEngine::default())
}

/// [`run_workload_obs`] with an explicit [`SchedEngine`] selection —
/// the parity suite drains the same inputs through both engines and
/// asserts byte-identical reports and traces.
pub fn run_workload_engine<'a, const N: usize>(
    inp: &WorkloadInputs<'a, N>,
    kind: PredictorKind,
    mut memory: Box<dyn ExpertMemory<N>>,
    compiled_pools: &[CompiledCorpus<N>],
    obs: &ObsSink,
    engine: SchedEngine,
) -> Result<WorkloadReport> {
    let (policy, learned) = validate_inputs(inp, kind, compiled_pools)?;
    let backend = memory.name().to_string();
    memory.set_obs(obs.clone());
    let cx = DrainCtx {
        inp,
        kind,
        learned,
        policy,
        compiled_pools,
        obs,
        tobs: resolve_tobs(inp, policy, obs),
    };
    let out = match engine {
        SchedEngine::Indexed => {
            let mut q = IndexedRunnable::new(policy);
            drain(&cx, &mut q, memory.as_mut())?
        }
        SchedEngine::LinearScan => {
            let mut q = ReferenceRunnable::new(policy);
            drain(&cx, &mut q, memory.as_mut())?
        }
    };
    Ok(fold_report(inp, kind, policy, backend, memory.stats(), out, obs))
}

/// Factory for one engine's memory replica: [`run_workload_sharded`]
/// calls it once per shard, inside that shard's worker thread.
pub type MemoryBuilder<const N: usize> = dyn Fn() -> Result<Box<dyn ExpertMemory<N>>> + Sync;

/// Shard-then-merge drain for the many-tenant regime: tenants are
/// partitioned by `tenant % shards`, each shard's sub-schedule drains on
/// its own full engine (its own memory replica from `build_memory`, its
/// own virtual clock) across `threads` workers, and the per-tenant
/// accumulators are merged in deterministic shard-index order — exact,
/// because the PR-6 histograms and every counter merge associatively
/// and a tenant's streams never cross shards.
///
/// Semantics: each shard is a full REPLICA engine (the point's whole
/// memory capacity), so the merged report models `shards` independent
/// servers splitting the tenant population — the scale-out analogue of
/// the single-engine run, not a partition of one engine's capacity
/// (that is the cluster backend's job).  Consequences, documented in
/// `rust/BENCHMARKS.md`: `virtual_secs` is the max over shard clocks
/// (wall time of the slowest replica), `max_*` counters sum as
/// aggregate capacity bounds, `completion_ids` is empty (per-shard
/// completion order does not interleave into one global order), and
/// shards drain with no-op observability sinks (use `shards = 1` for
/// traced runs).
pub fn run_workload_sharded<'a, const N: usize>(
    inp: &WorkloadInputs<'a, N>,
    kind: PredictorKind,
    build_memory: &MemoryBuilder<N>,
    compiled_pools: &[CompiledCorpus<N>],
    shards: usize,
    threads: usize,
) -> Result<WorkloadReport> {
    let shards = shards.max(1);
    if shards == 1 {
        return run_workload_compiled(inp, kind, build_memory()?, compiled_pools);
    }
    let (policy, _) = validate_inputs(inp, kind, compiled_pools)?;
    // partition the schedule by tenant shard; arrival order within a
    // shard is preserved, so each sub-schedule stays sorted
    let horizon_secs = (inp.schedule.horizon_us / 1e6).max(1e-9);
    let mut shard_schedules: Vec<Schedule> = (0..shards)
        .map(|_| Schedule {
            arrivals: Vec::new(),
            horizon_us: inp.schedule.horizon_us,
            offered_rps: 0.0,
        })
        .collect();
    for ev in &inp.schedule.arrivals {
        shard_schedules[ev.tenant % shards].arrivals.push(ev.clone());
    }
    for s in &mut shard_schedules {
        s.offered_rps = s.arrivals.len() as f64 / horizon_secs;
    }
    let shard_ids: Vec<usize> = (0..shards).collect();
    let outs = parallel_map(&shard_ids, threads, |&s| {
        let sinp = WorkloadInputs {
            schedule: &shard_schedules[s],
            ..*inp
        };
        let (policy, learned) = validate_inputs(&sinp, kind, compiled_pools)?;
        let mut memory = build_memory()?;
        let obs = ObsSink::default();
        memory.set_obs(obs.clone());
        let backend = memory.name().to_string();
        let cx = DrainCtx {
            inp: &sinp,
            kind,
            learned,
            policy,
            compiled_pools,
            obs: &obs,
            tobs: None,
        };
        let mut q = IndexedRunnable::new(policy);
        let out = drain(&cx, &mut q, memory.as_mut())?;
        Ok((out, memory.stats(), backend))
    })?;

    // merge in shard-index order — parallel_map writes results back by
    // index, so this order (and every merged number) is independent of
    // thread count and interleaving
    let mut acc: Vec<TenantAcc> = inp
        .spec
        .tenants
        .iter()
        .map(|_| TenantAcc::default())
        .collect();
    let mut counters = SchedCounters::default();
    let mut clock_us = 0.0f64;
    let mut memory_stats: Option<MemoryStats> = None;
    let mut backend = String::new();
    for (s, (out, ms, be)) in outs.into_iter().enumerate() {
        if s == 0 {
            backend = be;
        }
        for (a, b) in acc.iter_mut().zip(out.acc.iter()) {
            a.merge(b);
        }
        merge_counters(&mut counters, &out.counters);
        clock_us = clock_us.max(out.clock_us);
        memory_stats = Some(match memory_stats.take() {
            None => ms,
            Some(mut m) => {
                merge_memory_stats(&mut m, &ms);
                m
            }
        });
    }
    let out = DrainOutcome {
        acc,
        counters,
        clock_us,
        completion_ids: Vec::new(),
    };
    Ok(fold_report(
        inp,
        kind,
        policy,
        backend,
        memory_stats.unwrap_or_default(),
        out,
        &ObsSink::default(),
    ))
}

/// Sum two shard engines' counters.  The `max_*` peaks are summed, not
/// maxed: shard engines run concurrently in virtual time, so the sum is
/// the aggregate in-flight/backlog capacity bound across replicas.
fn merge_counters(a: &mut SchedCounters, b: &SchedCounters) {
    a.steps += b.steps;
    a.prefill_steps += b.prefill_steps;
    a.admissions += b.admissions;
    a.completions += b.completions;
    a.max_inflight += b.max_inflight;
    a.max_queue_depth += b.max_queue_depth;
    a.busy_us += b.busy_us;
    a.idle_us += b.idle_us;
    a.idle_while_runnable += b.idle_while_runnable;
    a.repeat_pick_with_waiters += b.repeat_pick_with_waiters;
    a.out_of_order_completions += b.out_of_order_completions;
}

/// Elementwise-sum two shard replicas' memory snapshots.  Structured
/// sub-stats merge only when both sides carry them (shards build
/// identical backends, so a mismatch means the shapes diverged — drop
/// to `None` rather than fabricate a partial merge).
fn merge_memory_stats(a: &mut MemoryStats, b: &MemoryStats) {
    a.demand_us += b.demand_us;
    a.prefetch_us += b.prefetch_us;
    a.stall_us += b.stall_us;
    a.resident += b.resident;
    if a.resident_per_depth.len() < b.resident_per_depth.len() {
        a.resident_per_depth.resize(b.resident_per_depth.len(), 0);
    }
    for (x, y) in a.resident_per_depth.iter_mut().zip(b.resident_per_depth.iter()) {
        *x += *y;
    }
    a.tiers = match (a.tiers.take(), &b.tiers) {
        (Some(mut t), Some(u)) => {
            t.merge(u);
            Some(t)
        }
        _ => None,
    };
    a.net = match (a.net.take(), &b.net) {
        (Some(mut n), Some(u)) => {
            n.merge(u);
            Some(n)
        }
        _ => None,
    };
}

/// Upfront validation shared by every entry point: config sanity,
/// learned-prediction coverage, and hand-built-schedule bounds — so the
/// drain loop never index-panics mid-run.
fn validate_inputs<'a, const N: usize>(
    inp: &WorkloadInputs<'a, N>,
    kind: PredictorKind,
    compiled_pools: &[CompiledCorpus<N>],
) -> Result<(SchedPolicy, Option<&'a [Vec<TracePredictions<N>>]>)> {
    inp.cfg.validate()?;
    inp.sim.validate()?;
    // the learned predictor replays precomputed per-trace predictions
    // (it cannot be factory-built); validate coverage up front
    let learned: Option<&'a [Vec<TracePredictions<N>>]> = if kind == PredictorKind::Learned {
        let l = inp.learned.ok_or_else(|| {
            anyhow::anyhow!(
                "the learned predictor needs precomputed per-trace predictions \
                 (WorkloadInputs::learned: one TracePredictions per pool trace)"
            )
        })?;
        anyhow::ensure!(
            l.len() == inp.pools.len(),
            "need one learned-prediction set per tenant pool ({} vs {})",
            l.len(),
            inp.pools.len()
        );
        for (t, (lp, pool)) in l.iter().zip(inp.pools.iter()).enumerate() {
            anyhow::ensure!(
                lp.len() == pool.len(),
                "tenant {t}: need one TracePredictions per pool trace ({} vs {})",
                lp.len(),
                pool.len()
            );
            for (i, (p, tr)) in lp.iter().zip(pool.iter()).enumerate() {
                anyhow::ensure!(
                    p.sets.len() >= tr.n_tokens() && p.n_layers >= inp.n_layers,
                    "tenant {t} trace {i}: predictions cover {}x{} tokens x layers \
                     but the run needs {}x{}",
                    p.sets.len(),
                    p.n_layers,
                    tr.n_tokens(),
                    inp.n_layers
                );
                // TracePredictions is all-pub and may be hand-built:
                // check the actual row lengths, not just the claimed
                // n_layers, so a ragged table cannot index-panic mid-run
                for (tok, row) in p.sets[..tr.n_tokens()].iter().enumerate() {
                    anyhow::ensure!(
                        row.len() >= inp.n_layers,
                        "tenant {t} trace {i}: prediction row for token {tok} has \
                         {} layers, run needs {}",
                        row.len(),
                        inp.n_layers
                    );
                }
            }
        }
        Some(l)
    } else {
        None
    };
    anyhow::ensure!(
        inp.pools.len() == inp.spec.tenants.len(),
        "need one trace pool per tenant"
    );
    anyhow::ensure!(
        compiled_pools.len() == inp.pools.len(),
        "need one compiled corpus per tenant pool"
    );
    // Schedule/ArrivalEvent are all-pub and may be hand-built: fail
    // loudly here instead of index-panicking mid-drain.  The generator
    // (`WorkloadSpec::generate`) upholds these by construction.
    for ev in &inp.schedule.arrivals {
        anyhow::ensure!(
            ev.tenant < inp.pools.len(),
            "arrival {}: tenant {} out of range",
            ev.request_id,
            ev.tenant
        );
        let pool = &inp.pools[ev.tenant];
        anyhow::ensure!(
            ev.trace_idx < pool.len(),
            "arrival {}: trace_idx {} out of range for tenant {}",
            ev.request_id,
            ev.trace_idx,
            ev.tenant
        );
        let n = pool[ev.trace_idx].n_tokens();
        anyhow::ensure!(
            ev.decode_tokens >= 1 && ev.prompt_tokens + ev.decode_tokens <= n,
            "arrival {}: prompt {} + decode {} exceeds the {}-token trace",
            ev.request_id,
            ev.prompt_tokens,
            ev.decode_tokens,
            n
        );
    }
    let policy = SchedPolicy::parse(&inp.cfg.policy)
        .ok_or_else(|| anyhow::anyhow!("unknown scheduler policy '{}'", inp.cfg.policy))?;
    Ok((policy, learned))
}

/// Per-tenant registry handles, resolved once (the registry lock is
/// never taken inside the drain loop).  `None` when the sink is off.
fn resolve_tobs<const N: usize>(
    inp: &WorkloadInputs<'_, N>,
    policy: SchedPolicy,
    obs: &ObsSink,
) -> Option<Vec<TenantObsHandles>> {
    obs.registry().map(|reg| {
        let pid = policy.id();
        inp.spec
            .tenants
            .iter()
            .map(|tp| {
                let labels: &[(&str, &str)] = &[("policy", pid), ("tenant", &tp.name)];
                TenantObsHandles {
                    ttft: reg.histogram("workload_ttft_us", labels),
                    tbt: reg.histogram("workload_tbt_us", labels),
                    latency: reg.histogram("workload_latency_us", labels),
                    queue: reg.histogram("workload_queue_us", labels),
                    tokens: reg.counter("workload_tokens", labels),
                    completions: reg.counter("workload_completions", labels),
                    cache_hits: reg.counter("workload_cache_hits", labels),
                    cache_misses: reg.counter("workload_cache_misses", labels),
                }
            })
            .collect()
    })
}

/// Everything the generic drain body reads besides the runnable set and
/// the memory backend.
struct DrainCtx<'r, 'a, const N: usize> {
    inp: &'r WorkloadInputs<'a, N>,
    kind: PredictorKind,
    learned: Option<&'a [Vec<TracePredictions<N>>]>,
    policy: SchedPolicy,
    compiled_pools: &'r [CompiledCorpus<N>],
    obs: &'r ObsSink,
    tobs: Option<Vec<TenantObsHandles>>,
}

/// What one drain produced, before folding into a [`WorkloadReport`] —
/// plain data only, so shard outcomes can cross the worker threads.
struct DrainOutcome {
    acc: Vec<TenantAcc>,
    counters: SchedCounters,
    clock_us: f64,
    completion_ids: Vec<u64>,
}

/// The drain loop, generic over the runnable-set engine — ONE body for
/// both [`SchedEngine`]s, so "byte-identical pick order" is the only
/// degree of freedom the parity suite has to pin.
fn drain<'a, const N: usize, Q: RunnableSet>(
    cx: &DrainCtx<'_, 'a, N>,
    queue: &mut Q,
    memory: &mut dyn ExpertMemory<N>,
) -> Result<DrainOutcome> {
    let inp = cx.inp;
    let obs = cx.obs;
    let tobs = &cx.tobs;
    let n_layers = inp.n_layers;
    let n_slots = inp.cfg.max_concurrency;
    let id_cap = inp.cfg.completion_log_cap;
    let params = PredictorParams {
        eam: inp.eam,
        predict_top_k: inp.sim.predict_top_k,
        n_layers,
        n_experts: inp.n_experts,
        fit_traces: inp.fit_traces,
    };
    // per-slot predictor replicas, built lazily on a slot's first use:
    // memory tracks the concurrency high-water mark, not the configured
    // limit (a 10⁶-stream limit must not allocate 10⁶ EAMC tables up
    // front).  A slot keeps its predictor across the requests it serves
    // — identical state evolution to eager construction, since building
    // is deterministic and a never-used predictor observes nothing.
    let mut predictors: Vec<Option<Box<dyn ExpertPredictor<N> + 'a>>> = Vec::new();
    let mut soa = SoaStreams::default();

    let mut acc: Vec<TenantAcc> = inp
        .spec
        .tenants
        .iter()
        .map(|_| TenantAcc::default())
        .collect();
    let mut counters = SchedCounters::default();
    let mut completion_ids: Vec<u64> = Vec::new();
    let mut last_completed_id: Option<u64> = None;

    let arrivals = &inp.schedule.arrivals;
    // per-token prediction buffer, reused across every decode step
    let mut pred_sets = vec![ExpertSet::<N>::EMPTY; n_layers];
    let mut clock = 0.0f64;
    let mut next = 0usize; // next arrival to admit (FIFO admission queue)
    let mut due = 0usize; // arrivals with arrival_us <= clock
    let mut last_stepped: Option<u64> = None;

    loop {
        obs.set_now_us(clock);
        // ---- admit every due arrival up to the concurrency limit
        while due < arrivals.len() && arrivals[due].arrival_us <= clock {
            due += 1;
        }
        // peak backlog is sampled before admission drains it, so an
        // arrival burst admitted within this same iteration still
        // reports its true queue depth
        counters.max_queue_depth = counters.max_queue_depth.max(due - next);
        while next < due && queue.len() < n_slots {
            let ev = &arrivals[next];
            let slot = queue.acquire_slot();
            soa.ensure(slot);
            if predictors.len() <= slot {
                predictors.resize_with(slot + 1, || None);
            }
            if cx.kind == PredictorKind::Learned {
                // learned predictions are per request trace: the slot
                // replays exactly this trace's precomputed sets
                let l = cx.learned.expect("learned predictions validated upfront");
                predictors[slot] =
                    Some(Box::new(CachedPredictor::new(&l[ev.tenant][ev.trace_idx])));
            } else if predictors[slot].is_none() {
                predictors[slot] = Some(factory::build(cx.kind, &params)?);
            }
            let pred = predictors[slot].as_mut().expect("slot predictor ensured above");
            pred.begin_prompt(&inp.pools[ev.tenant][ev.trace_idx]);
            let queued_us = clock - ev.arrival_us;
            acc[ev.tenant].queue.record(queued_us);
            if let Some(h) = tobs {
                h[ev.tenant].queue.record(queued_us);
            }
            obs.emit(|ts| TraceEvent::RequestBegin {
                ts_us: ts,
                request: ev.request_id,
                tenant: ev.tenant as u32,
            });
            soa.tenant[slot] = ev.tenant as u32;
            soa.request_id[slot] = ev.request_id;
            soa.trace_idx[slot] = ev.trace_idx as u32;
            soa.prompt[slot] = ev.prompt_tokens as u32;
            soa.decode[slot] = ev.decode_tokens as u32;
            soa.decoded[slot] = 0;
            soa.arrival_us[slot] = ev.arrival_us;
            soa.last_token_us[slot] = 0.0;
            soa.prefilled[slot] = false;
            queue.admit(slot, ev.decode_tokens);
            counters.admissions += 1;
            next += 1;
        }
        counters.max_inflight = counters.max_inflight.max(queue.len());

        // ---- idle: jump the virtual clock to the next arrival
        if queue.len() == 0 {
            if next >= arrivals.len() {
                break; // drained
            }
            if due > next {
                // defensive: a due arrival with a free engine must admit
                counters.idle_while_runnable += 1;
            }
            let t = arrivals[next].arrival_us;
            counters.idle_us += (t - clock).max(0.0);
            clock = clock.max(t);
            continue;
        }

        // ---- pick a stream (O(1) amortized on the indexed engine)
        let slot = queue.pick(&soa.decode, &soa.decoded);
        if queue.len() >= 2 && last_stepped == Some(soa.request_id[slot]) {
            counters.repeat_pick_with_waiters += 1;
        }
        last_stepped = Some(soa.request_id[slot]);

        // ---- execute one unit of work (whole prefill or one token)
        let was_decode = soa.prefilled[slot];
        let tenant = soa.tenant[slot] as usize;
        let cost;
        {
            let trace = &inp.pools[tenant][soa.trace_idx[slot] as usize];
            let ctrace = &cx.compiled_pools[tenant][soa.trace_idx[slot] as usize];
            let pred = predictors[slot].as_mut().expect("admitted slot has a predictor");
            let ta = &mut acc[tenant];
            if !was_decode {
                // prefill: warm the shared residency (unmeasured — the
                // per-prompt warm-up epoch), still paying fetch traffic
                let mut fetch_us = 0.0;
                let prompt = soa.prompt[slot] as usize;
                for t in 0..prompt {
                    let ctx = DecodeContext { trace, t };
                    for l in 0..n_layers {
                        let truth = ctrace.set(t, l);
                        fetch_us += memory.lookup_set(l, truth, false).fetch_us;
                        memory.end_layer();
                        pred.observe(&ctx, l, truth);
                    }
                }
                soa.prefilled[slot] = true;
                counters.prefill_steps += 1;
                cost = inp.cfg.prefill_us_per_token * prompt as f64 + fetch_us;
            } else {
                // one decode token: predict every layer in ONE call
                // (the replay engine's timing), then prefetch → reveal
                // truth per layer
                let t = (soa.prompt[slot] + soa.decoded[slot]) as usize;
                let ctx = DecodeContext { trace, t };
                pred.predict_layers(&ctx, 0..n_layers, &mut pred_sets);
                let mark = memory.cost_marks();
                for l in 0..n_layers {
                    let truth = ctrace.set(t, l);
                    let predicted = pred_sets[l];
                    let pf = memory.prefetch(l, predicted);
                    ta.cache.prefetches += pf.issued;
                    ta.cache.wasted_prefetches += pf.too_late;
                    ta.cache.prediction_total += truth.len() as u64;
                    ta.cache.prediction_hits += truth.overlap(predicted) as u64;
                    let batch = memory.lookup_set(l, truth, true);
                    let hits = batch.hits.len() as u64;
                    ta.cache.hits += hits;
                    ta.cache.misses += truth.len() as u64 - hits;
                    if let Some(h) = tobs {
                        h[tenant].cache_hits.add(hits);
                        h[tenant].cache_misses.add(truth.len() as u64 - hits);
                    }
                    ta.cache.transfer_us += batch.fetch_us;
                    memory.end_layer();
                    pred.observe(&ctx, l, truth);
                }
                let after = memory.cost_marks();
                cost = inp.cfg.token_compute_us + (after.0 - mark.0) + (after.1 - mark.1);
                soa.decoded[slot] += 1;
                counters.steps += 1;
            }
        }
        if was_decode {
            // Chrome "X" span for the token: starts at the sink's
            // still-token-start clock, spans the step's virtual cost.
            obs.emit(|ts| TraceEvent::DecodeStep {
                ts_us: ts,
                request: soa.request_id[slot],
                tenant: soa.tenant[slot],
                token: soa.decoded[slot] - 1,
                cost_us: cost,
            });
        }
        clock += cost;
        counters.busy_us += cost;
        obs.set_now_us(clock);

        // ---- token SLO accounting + completion
        let mut completed = false;
        if was_decode {
            let ta = &mut acc[tenant];
            if soa.decoded[slot] == 1 {
                let v = clock - soa.arrival_us[slot];
                ta.ttft.record(v);
                if let Some(h) = tobs {
                    h[tenant].ttft.record(v);
                }
            } else {
                let v = clock - soa.last_token_us[slot];
                ta.tbt.record(v);
                if let Some(h) = tobs {
                    h[tenant].tbt.record(v);
                }
            }
            soa.last_token_us[slot] = clock;
            completed = soa.decoded[slot] == soa.decode[slot];
        }
        if completed {
            let pred = predictors[slot].as_mut().expect("admitted slot has a predictor");
            pred.end_prompt(&inp.pools[tenant][soa.trace_idx[slot] as usize]);
            let ta = &mut acc[tenant];
            let latency_us = clock - soa.arrival_us[slot];
            ta.latency.record(latency_us);
            ta.completed += 1;
            ta.tokens += soa.decode[slot] as u64;
            if let Some(h) = tobs {
                let th = &h[tenant];
                th.latency.record(latency_us);
                th.tokens.add(soa.decode[slot] as u64);
                th.completions.inc();
            }
            obs.emit(|ts| TraceEvent::RequestEnd {
                ts_us: ts,
                request: soa.request_id[slot],
                tenant: soa.tenant[slot],
            });
            let rid = soa.request_id[slot];
            if completion_ids.len() < id_cap {
                completion_ids.push(rid);
            }
            match last_completed_id {
                Some(prev) if rid < prev => counters.out_of_order_completions += 1,
                _ => last_completed_id = Some(rid),
            }
            counters.completions += 1;
            queue.stepped(slot, StepOutcome::Complete);
        } else if was_decode {
            queue.stepped(slot, StepOutcome::Decode);
        } else {
            queue.stepped(slot, StepOutcome::Prefill);
        }
    }

    Ok(DrainOutcome {
        acc,
        counters,
        clock_us: clock,
        completion_ids,
    })
}

/// Fold a drain outcome into the report (and the registry gauges, when
/// a sink is attached).
fn fold_report<const N: usize>(
    inp: &WorkloadInputs<'_, N>,
    kind: PredictorKind,
    policy: SchedPolicy,
    backend: String,
    memory_stats: MemoryStats,
    out: DrainOutcome,
    obs: &ObsSink,
) -> WorkloadReport {
    let virtual_secs = out.clock_us / 1e6;
    if let Some(reg) = obs.registry() {
        reg.gauge("workload_virtual_secs", &[("policy", policy.id())])
            .set(virtual_secs);
        // world shape, so wide-world traces are self-describing
        reg.gauge("expert_set_width_words", &[]).set(N as f64);
        reg.gauge("n_experts", &[]).set(inp.n_experts as f64);
    }
    let mut aggregate = TenantAcc::default();
    for ta in &out.acc {
        aggregate.merge(ta);
    }
    let total_tokens: u64 = out.acc.iter().map(|a| a.tokens).sum();
    let completions = out.counters.completions;
    let tenants = out
        .acc
        .into_iter()
        .zip(inp.spec.tenants.iter())
        .map(|(a, t)| a.into_slo(&t.name))
        .collect();
    let denom = virtual_secs.max(1e-9);
    WorkloadReport {
        policy: policy.id().to_string(),
        backend,
        predictor: kind.id().to_string(),
        offered_rps: inp.schedule.offered_rps,
        completed_rps: completions as f64 / denom,
        tokens_per_sec: total_tokens as f64 / denom,
        virtual_secs,
        counters: out.counters,
        aggregate: aggregate.into_slo("all"),
        tenants,
        memory: memory_stats,
        completion_ids: out.completion_ids,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_ids_round_trip() {
        for p in SchedPolicy::ALL {
            assert_eq!(SchedPolicy::parse(p.id()), Some(p));
        }
        assert_eq!(SchedPolicy::parse("rr"), Some(SchedPolicy::RoundRobin));
        assert_eq!(
            SchedPolicy::parse("shortest-remaining"),
            Some(SchedPolicy::ShortestRemaining)
        );
        assert_eq!(SchedPolicy::parse("magic"), None);
    }

    #[test]
    fn per_stream_state_fits_the_scale_budget() {
        let b = inflight_state_bytes_per_stream();
        assert!(b <= 128, "{b} bytes/stream exceeds the 128-byte budget");
    }

    #[test]
    fn counter_merge_sums_every_field() {
        let mut a = SchedCounters {
            steps: 1,
            max_inflight: 3,
            busy_us: 10.0,
            ..Default::default()
        };
        let b = SchedCounters {
            steps: 2,
            prefill_steps: 4,
            admissions: 5,
            completions: 5,
            max_inflight: 2,
            max_queue_depth: 7,
            busy_us: 2.5,
            idle_us: 1.5,
            idle_while_runnable: 1,
            repeat_pick_with_waiters: 2,
            out_of_order_completions: 3,
        };
        merge_counters(&mut a, &b);
        assert_eq!(a.steps, 3);
        assert_eq!(a.prefill_steps, 4);
        assert_eq!(a.admissions, 5);
        assert_eq!(a.completions, 5);
        assert_eq!(a.max_inflight, 5);
        assert_eq!(a.max_queue_depth, 7);
        assert!((a.busy_us - 12.5).abs() < 1e-12);
        assert!((a.idle_us - 1.5).abs() < 1e-12);
        assert_eq!(a.idle_while_runnable, 1);
        assert_eq!(a.repeat_pick_with_waiters, 2);
        assert_eq!(a.out_of_order_completions, 3);
    }
}
