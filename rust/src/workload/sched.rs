//! Virtual-time multi-tenant scheduler: interleaves the decode steps of
//! every in-flight stream against ONE shared [`ExpertMemory`].
//!
//! The engine model matches the serving coordinator's reality (one edge
//! accelerator == one execution stream): at any instant exactly one
//! stream is either prefilling or decoding one token, and every stream's
//! lookups/prefetches hit the same residency backend — so streams evict
//! each other's experts, which is precisely the contention regime the
//! single-stream Fig-7 replay cannot show.
//!
//! Time is virtual (µs): a decode step occupies the engine for
//! `token_compute_us` plus the memory model's demand+stall delta for
//! that token; prefill occupies `prefill_us_per_token × prompt` plus its
//! fetch traffic.  No wall clock is ever read, so a seeded workload
//! replays byte-identically — the CI perf gate depends on this.

use std::sync::Arc;

use crate::config::{EamConfig, SimConfig, WorkloadConfig};
use crate::memory::ExpertMemory;
use crate::metrics::Counter;
use crate::obs::{AtomicHist, ObsSink, TraceEvent};
use crate::predictor::{
    factory, CachedPredictor, DecodeContext, ExpertPredictor, NoPrefetch, PredictorKind,
    PredictorParams, TracePredictions,
};
use crate::trace::{CompiledCorpus, PromptTrace};
use crate::util::ExpertSet;
use crate::workload::profile::{Schedule, WorkloadSpec};
use crate::workload::slo::{TenantAcc, WorkloadReport};
use crate::Result;

/// Per-tenant registry handles, grabbed once at drain start when an
/// active [`ObsSink`] is attached — the drain loop then records through
/// lock-free atomics only.
struct TenantObsHandles {
    ttft: Arc<AtomicHist>,
    tbt: Arc<AtomicHist>,
    latency: Arc<AtomicHist>,
    queue: Arc<AtomicHist>,
    tokens: Arc<Counter>,
    completions: Arc<Counter>,
    cache_hits: Arc<Counter>,
    cache_misses: Arc<Counter>,
}

/// Which in-flight stream decodes the next token.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedPolicy {
    /// Run the earliest-admitted stream to completion (no interleaving —
    /// the per-stream-locality-preserving baseline).
    Fcfs,
    /// One token per stream, cycling in admission order.
    RoundRobin,
    /// Step the stream with the fewest remaining decode tokens
    /// (shortest-remaining-decode; ties broken by admission order).
    ShortestRemaining,
}

impl SchedPolicy {
    pub const ALL: [SchedPolicy; 3] = [
        SchedPolicy::Fcfs,
        SchedPolicy::RoundRobin,
        SchedPolicy::ShortestRemaining,
    ];

    /// Config identifier (accepted by [`WorkloadConfig::policy`]).
    pub fn id(&self) -> &'static str {
        match self {
            SchedPolicy::Fcfs => "fcfs",
            SchedPolicy::RoundRobin => "round-robin",
            SchedPolicy::ShortestRemaining => "srd",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "fcfs" => Some(SchedPolicy::Fcfs),
            "round-robin" | "rr" => Some(SchedPolicy::RoundRobin),
            "srd" | "shortest-remaining" | "shortest-remaining-decode" => {
                Some(SchedPolicy::ShortestRemaining)
            }
            _ => None,
        }
    }
}

/// Scheduler invariant counters — deterministic integers the perf gate
/// and the invariant tests key on.
#[derive(Debug, Clone, Default)]
pub struct SchedCounters {
    /// Decode steps executed (one token each).
    pub steps: u64,
    /// Prefill steps executed (one whole prompt each).
    pub prefill_steps: u64,
    pub admissions: u64,
    pub completions: u64,
    pub max_inflight: usize,
    /// Largest number of arrived-but-unadmitted requests observed.
    pub max_queue_depth: usize,
    /// Virtual µs the engine spent executing.
    pub busy_us: f64,
    /// Virtual µs the engine sat idle waiting for the next arrival.
    pub idle_us: f64,
    /// Work-conservation violations: the engine idled while a runnable
    /// stream or a due arrival existed.  Must stay 0.
    pub idle_while_runnable: u64,
    /// Picks of the same stream as the previous step while another
    /// runnable stream existed.  Always 0 under round-robin (the
    /// no-starvation guarantee); positive by design under FCFS.
    pub repeat_pick_with_waiters: u64,
}

/// Everything one simulator run reads.
///
/// Generic over the [`ExpertSet`] word width `N` (default 1 = up to 64
/// experts); wide worlds carry their width through the learned
/// predictions and the compiled pools into the drain loop below.
pub struct WorkloadInputs<'a, const N: usize = 1> {
    pub spec: &'a WorkloadSpec,
    pub schedule: &'a Schedule,
    /// `pools[t]` backs tenant `t`'s requests.
    pub pools: &'a [Vec<PromptTrace>],
    /// Training traces for offline-fitted predictors (EAMC, popularity).
    pub fit_traces: &'a [PromptTrace],
    /// Precomputed learned predictions, `learned[t][i]` parallel to
    /// `pools[t][i]` (required iff the run uses
    /// [`PredictorKind::Learned`]; each admitted request replays its
    /// trace's predictions through a [`CachedPredictor`], exactly as the
    /// Fig-7 sweep does).
    pub learned: Option<&'a [Vec<TracePredictions<N>>]>,
    pub cfg: &'a WorkloadConfig,
    pub sim: &'a SimConfig,
    pub eam: &'a EamConfig,
    pub n_layers: usize,
    pub n_experts: usize,
}

/// One in-flight decode stream.
struct Stream {
    tenant: usize,
    request_id: u64,
    trace_idx: usize,
    prompt: usize,
    decode: usize,
    arrival_us: f64,
    slot: usize,
    decoded: usize,
    prefilled: bool,
    last_token_us: f64,
}

/// Run one multi-tenant workload to drain against `memory`.
///
/// Per decode token the engine mirrors `SimEngine::run_prompt`'s
/// measured phase (ONE `predict_layers` call for the whole token, then
/// per layer prefetch → lookup ground truth → end_layer → observe);
/// prefill mirrors the serving engine's warm-up (residency moves,
/// hit/miss counters stay decode-only, fetch traffic still costs
/// virtual time).  Predictor state lives in one replica per concurrency
/// slot, so a slot's EAMC grows across the requests it serves exactly
/// as a serial engine's would; `PredictorKind::Learned` instead replays
/// each request's precomputed [`TracePredictions`]
/// (`WorkloadInputs::learned`) through a per-request [`CachedPredictor`].
pub fn run_workload<const N: usize>(
    inp: &WorkloadInputs<'_, N>,
    kind: PredictorKind,
    memory: Box<dyn ExpertMemory<N>>,
) -> Result<WorkloadReport> {
    // compile each tenant pool once; requests replay pool traces many
    // times over, and `sweep_load` shares one compilation for the whole
    // grid via `run_workload_compiled`
    let compiled: Vec<CompiledCorpus<N>> =
        inp.pools.iter().map(|p| CompiledCorpus::compile(p)).collect();
    run_workload_compiled(inp, kind, memory, &compiled)
}

/// [`run_workload`] over pre-compiled tenant pools (index-parallel to
/// `inp.pools`); the load-sweep grid compiles once and every worker
/// shares the `Arc`-backed tables.
pub fn run_workload_compiled<'a, const N: usize>(
    inp: &WorkloadInputs<'a, N>,
    kind: PredictorKind,
    memory: Box<dyn ExpertMemory<N>>,
    compiled_pools: &[CompiledCorpus<N>],
) -> Result<WorkloadReport> {
    run_workload_obs(inp, kind, memory, compiled_pools, &ObsSink::default())
}

/// [`run_workload_compiled`] with an observability sink attached: the
/// drain stamps the sink's virtual clock in lock-step with the
/// scheduler clock, emits request/decode trace events, and mirrors the
/// per-tenant SLO accumulators into labeled registry metrics.  With the
/// default (no-op) sink this is exactly `run_workload_compiled` — the
/// report is byte-identical either way, because tracing never touches
/// the virtual-time arithmetic.
pub fn run_workload_obs<'a, const N: usize>(
    inp: &WorkloadInputs<'a, N>,
    kind: PredictorKind,
    mut memory: Box<dyn ExpertMemory<N>>,
    compiled_pools: &[CompiledCorpus<N>],
    obs: &ObsSink,
) -> Result<WorkloadReport> {
    inp.cfg.validate()?;
    inp.sim.validate()?;
    // the learned predictor replays precomputed per-trace predictions
    // (it cannot be factory-built); validate coverage up front so the
    // drain never index-panics mid-run
    let learned: Option<&'a [Vec<TracePredictions<N>>]> = if kind == PredictorKind::Learned {
        let l = inp.learned.ok_or_else(|| {
            anyhow::anyhow!(
                "the learned predictor needs precomputed per-trace predictions \
                 (WorkloadInputs::learned: one TracePredictions per pool trace)"
            )
        })?;
        anyhow::ensure!(
            l.len() == inp.pools.len(),
            "need one learned-prediction set per tenant pool ({} vs {})",
            l.len(),
            inp.pools.len()
        );
        for (t, (lp, pool)) in l.iter().zip(inp.pools.iter()).enumerate() {
            anyhow::ensure!(
                lp.len() == pool.len(),
                "tenant {t}: need one TracePredictions per pool trace ({} vs {})",
                lp.len(),
                pool.len()
            );
            for (i, (p, tr)) in lp.iter().zip(pool.iter()).enumerate() {
                anyhow::ensure!(
                    p.sets.len() >= tr.n_tokens() && p.n_layers >= inp.n_layers,
                    "tenant {t} trace {i}: predictions cover {}x{} tokens x layers \
                     but the run needs {}x{}",
                    p.sets.len(),
                    p.n_layers,
                    tr.n_tokens(),
                    inp.n_layers
                );
                // TracePredictions is all-pub and may be hand-built:
                // check the actual row lengths, not just the claimed
                // n_layers, so a ragged table cannot index-panic mid-run
                for (tok, row) in p.sets[..tr.n_tokens()].iter().enumerate() {
                    anyhow::ensure!(
                        row.len() >= inp.n_layers,
                        "tenant {t} trace {i}: prediction row for token {tok} has \
                         {} layers, run needs {}",
                        row.len(),
                        inp.n_layers
                    );
                }
            }
        }
        Some(l)
    } else {
        None
    };
    anyhow::ensure!(
        inp.pools.len() == inp.spec.tenants.len(),
        "need one trace pool per tenant"
    );
    anyhow::ensure!(
        compiled_pools.len() == inp.pools.len(),
        "need one compiled corpus per tenant pool"
    );
    // Schedule/ArrivalEvent are all-pub and may be hand-built: fail
    // loudly here instead of index-panicking mid-drain.  The generator
    // (`WorkloadSpec::generate`) upholds these by construction.
    for ev in &inp.schedule.arrivals {
        anyhow::ensure!(
            ev.tenant < inp.pools.len(),
            "arrival {}: tenant {} out of range",
            ev.request_id,
            ev.tenant
        );
        let pool = &inp.pools[ev.tenant];
        anyhow::ensure!(
            ev.trace_idx < pool.len(),
            "arrival {}: trace_idx {} out of range for tenant {}",
            ev.request_id,
            ev.trace_idx,
            ev.tenant
        );
        let n = pool[ev.trace_idx].n_tokens();
        anyhow::ensure!(
            ev.decode_tokens >= 1 && ev.prompt_tokens + ev.decode_tokens <= n,
            "arrival {}: prompt {} + decode {} exceeds the {}-token trace",
            ev.request_id,
            ev.prompt_tokens,
            ev.decode_tokens,
            n
        );
    }
    let policy = SchedPolicy::parse(&inp.cfg.policy)
        .ok_or_else(|| anyhow::anyhow!("unknown scheduler policy '{}'", inp.cfg.policy))?;

    let backend = memory.name().to_string();
    memory.set_obs(obs.clone());
    // Per-tenant registry handles, resolved once (the registry lock is
    // never taken inside the drain loop).  `None` when the sink is off.
    let tobs: Option<Vec<TenantObsHandles>> = obs.registry().map(|reg| {
        let pid = policy.id();
        inp.spec
            .tenants
            .iter()
            .map(|tp| {
                let labels: &[(&str, &str)] = &[("policy", pid), ("tenant", &tp.name)];
                TenantObsHandles {
                    ttft: reg.histogram("workload_ttft_us", labels),
                    tbt: reg.histogram("workload_tbt_us", labels),
                    latency: reg.histogram("workload_latency_us", labels),
                    queue: reg.histogram("workload_queue_us", labels),
                    tokens: reg.counter("workload_tokens", labels),
                    completions: reg.counter("workload_completions", labels),
                    cache_hits: reg.counter("workload_cache_hits", labels),
                    cache_misses: reg.counter("workload_cache_misses", labels),
                }
            })
            .collect()
    });
    let n_layers = inp.n_layers;
    let n_slots = inp.cfg.max_concurrency;
    let params = PredictorParams {
        eam: inp.eam,
        predict_top_k: inp.sim.predict_top_k,
        n_layers,
        n_experts: inp.n_experts,
        fit_traces: inp.fit_traces,
    };
    let mut predictors: Vec<Box<dyn ExpertPredictor<N> + 'a>> = (0..n_slots)
        .map(|_| -> Result<Box<dyn ExpertPredictor<N> + 'a>> {
            Ok(match kind {
                // placeholder: each admission swaps in that request's
                // CachedPredictor before the slot's first use
                PredictorKind::Learned => Box::new(NoPrefetch),
                _ => factory::build(kind, &params)?,
            })
        })
        .collect::<Result<_>>()?;
    let mut slot_busy = vec![false; n_slots];

    let mut acc: Vec<TenantAcc> = inp
        .spec
        .tenants
        .iter()
        .map(|_| TenantAcc::default())
        .collect();
    let mut counters = SchedCounters::default();
    let mut completion_ids: Vec<u64> = Vec::new();

    let arrivals = &inp.schedule.arrivals;
    // per-token prediction buffer, reused across every decode step
    let mut pred_sets = vec![ExpertSet::<N>::EMPTY; n_layers];
    let mut clock = 0.0f64;
    let mut next = 0usize; // next arrival to admit (FIFO admission queue)
    let mut due = 0usize; // arrivals with arrival_us <= clock
    let mut inflight: Vec<Stream> = Vec::new();
    let mut rr_idx = 0usize;
    let mut last_stepped: Option<u64> = None;

    loop {
        obs.set_now_us(clock);
        // ---- admit every due arrival up to the concurrency limit
        while due < arrivals.len() && arrivals[due].arrival_us <= clock {
            due += 1;
        }
        while next < due && inflight.len() < n_slots {
            let ev = &arrivals[next];
            let slot = slot_busy
                .iter()
                .position(|b| !*b)
                .expect("free predictor slot under the concurrency limit");
            slot_busy[slot] = true;
            if let Some(l) = learned {
                // learned predictions are per request trace: the slot
                // replays exactly this trace's precomputed sets
                predictors[slot] = Box::new(CachedPredictor::new(&l[ev.tenant][ev.trace_idx]));
            }
            predictors[slot].begin_prompt(&inp.pools[ev.tenant][ev.trace_idx]);
            let queued_us = clock - ev.arrival_us;
            acc[ev.tenant].queue.record(queued_us);
            if let Some(h) = &tobs {
                h[ev.tenant].queue.record(queued_us);
            }
            obs.emit(|ts| TraceEvent::RequestBegin {
                ts_us: ts,
                request: ev.request_id,
                tenant: ev.tenant as u32,
            });
            inflight.push(Stream {
                tenant: ev.tenant,
                request_id: ev.request_id,
                trace_idx: ev.trace_idx,
                prompt: ev.prompt_tokens,
                decode: ev.decode_tokens,
                arrival_us: ev.arrival_us,
                slot,
                decoded: 0,
                prefilled: false,
                last_token_us: 0.0,
            });
            counters.admissions += 1;
            next += 1;
        }
        counters.max_queue_depth = counters.max_queue_depth.max(due - next);
        counters.max_inflight = counters.max_inflight.max(inflight.len());

        // ---- idle: jump the virtual clock to the next arrival
        if inflight.is_empty() {
            if next >= arrivals.len() {
                break; // drained
            }
            if due > next {
                // defensive: a due arrival with a free engine must admit
                counters.idle_while_runnable += 1;
            }
            let t = arrivals[next].arrival_us;
            counters.idle_us += (t - clock).max(0.0);
            clock = clock.max(t);
            continue;
        }

        // ---- pick a stream
        let i = match policy {
            SchedPolicy::Fcfs => 0,
            SchedPolicy::RoundRobin => {
                if rr_idx >= inflight.len() {
                    rr_idx = 0;
                }
                rr_idx
            }
            SchedPolicy::ShortestRemaining => {
                let mut best = 0usize;
                for j in 1..inflight.len() {
                    let rj = inflight[j].decode - inflight[j].decoded;
                    let rb = inflight[best].decode - inflight[best].decoded;
                    if rj < rb {
                        best = j;
                    }
                }
                best
            }
        };
        if inflight.len() >= 2 && last_stepped == Some(inflight[i].request_id) {
            counters.repeat_pick_with_waiters += 1;
        }
        last_stepped = Some(inflight[i].request_id);

        // ---- execute one unit of work (whole prefill or one token)
        let was_decode;
        let cost;
        {
            let s = &mut inflight[i];
            let trace = &inp.pools[s.tenant][s.trace_idx];
            let ctrace = &compiled_pools[s.tenant][s.trace_idx];
            let pred = predictors[s.slot].as_mut();
            let ta = &mut acc[s.tenant];
            was_decode = s.prefilled;
            if !s.prefilled {
                // prefill: warm the shared residency (unmeasured — the
                // per-prompt warm-up epoch), still paying fetch traffic
                let mut fetch_us = 0.0;
                for t in 0..s.prompt {
                    let ctx = DecodeContext { trace, t };
                    for l in 0..n_layers {
                        let truth = ctrace.set(t, l);
                        fetch_us += memory.lookup_set(l, truth, false).fetch_us;
                        memory.end_layer();
                        pred.observe(&ctx, l, truth);
                    }
                }
                s.prefilled = true;
                counters.prefill_steps += 1;
                cost = inp.cfg.prefill_us_per_token * s.prompt as f64 + fetch_us;
            } else {
                // one decode token: predict every layer in ONE call
                // (the replay engine's timing), then prefetch → reveal
                // truth per layer
                let t = s.prompt + s.decoded;
                let ctx = DecodeContext { trace, t };
                pred.predict_layers(&ctx, 0..n_layers, &mut pred_sets);
                let mark = memory.cost_marks();
                for l in 0..n_layers {
                    let truth = ctrace.set(t, l);
                    let predicted = pred_sets[l];
                    let pf = memory.prefetch(l, predicted);
                    ta.cache.prefetches += pf.issued;
                    ta.cache.wasted_prefetches += pf.too_late;
                    ta.cache.prediction_total += truth.len() as u64;
                    ta.cache.prediction_hits += truth.overlap(predicted) as u64;
                    let batch = memory.lookup_set(l, truth, true);
                    let hits = batch.hits.len() as u64;
                    ta.cache.hits += hits;
                    ta.cache.misses += truth.len() as u64 - hits;
                    if let Some(h) = &tobs {
                        h[s.tenant].cache_hits.add(hits);
                        h[s.tenant].cache_misses.add(truth.len() as u64 - hits);
                    }
                    ta.cache.transfer_us += batch.fetch_us;
                    memory.end_layer();
                    pred.observe(&ctx, l, truth);
                }
                let after = memory.cost_marks();
                cost = inp.cfg.token_compute_us + (after.0 - mark.0) + (after.1 - mark.1);
                s.decoded += 1;
                counters.steps += 1;
            }
        }
        if was_decode {
            // Chrome "X" span for the token: starts at the sink's
            // still-token-start clock, spans the step's virtual cost.
            let s = &inflight[i];
            obs.emit(|ts| TraceEvent::DecodeStep {
                ts_us: ts,
                request: s.request_id,
                tenant: s.tenant as u32,
                token: (s.decoded - 1) as u32,
                cost_us: cost,
            });
        }
        clock += cost;
        counters.busy_us += cost;
        obs.set_now_us(clock);

        // ---- token SLO accounting + completion
        let mut completed = false;
        {
            let s = &mut inflight[i];
            if was_decode {
                let ta = &mut acc[s.tenant];
                if s.decoded == 1 {
                    let v = clock - s.arrival_us;
                    ta.ttft.record(v);
                    if let Some(h) = &tobs {
                        h[s.tenant].ttft.record(v);
                    }
                } else {
                    let v = clock - s.last_token_us;
                    ta.tbt.record(v);
                    if let Some(h) = &tobs {
                        h[s.tenant].tbt.record(v);
                    }
                }
                s.last_token_us = clock;
                completed = s.decoded == s.decode;
            }
        }
        if completed {
            let s = inflight.remove(i);
            predictors[s.slot].end_prompt(&inp.pools[s.tenant][s.trace_idx]);
            slot_busy[s.slot] = false;
            let ta = &mut acc[s.tenant];
            let latency_us = clock - s.arrival_us;
            ta.latency.record(latency_us);
            ta.completed += 1;
            ta.tokens += s.decode as u64;
            if let Some(h) = &tobs {
                let th = &h[s.tenant];
                th.latency.record(latency_us);
                th.tokens.add(s.decode as u64);
                th.completions.inc();
            }
            obs.emit(|ts| TraceEvent::RequestEnd {
                ts_us: ts,
                request: s.request_id,
                tenant: s.tenant as u32,
            });
            completion_ids.push(s.request_id);
            counters.completions += 1;
            if rr_idx > i {
                rr_idx -= 1; // keep the cursor on the same logical stream
            }
        } else if policy == SchedPolicy::RoundRobin {
            rr_idx = i + 1; // advance past the stream just stepped
        }
    }

    // ---- fold the accumulators into the report
    let virtual_secs = clock / 1e6;
    if let Some(reg) = obs.registry() {
        reg.gauge("workload_virtual_secs", &[("policy", policy.id())])
            .set(virtual_secs);
        // world shape, so wide-world traces are self-describing
        reg.gauge("expert_set_width_words", &[]).set(N as f64);
        reg.gauge("n_experts", &[]).set(inp.n_experts as f64);
    }
    let mut aggregate = TenantAcc::default();
    for ta in &acc {
        aggregate.merge(ta);
    }
    let total_tokens: u64 = acc.iter().map(|a| a.tokens).sum();
    let tenants = acc
        .into_iter()
        .zip(inp.spec.tenants.iter())
        .map(|(a, t)| a.into_slo(&t.name))
        .collect();
    let denom = virtual_secs.max(1e-9);
    Ok(WorkloadReport {
        policy: policy.id().to_string(),
        backend,
        predictor: kind.id().to_string(),
        offered_rps: inp.schedule.offered_rps,
        completed_rps: counters.completions as f64 / denom,
        tokens_per_sec: total_tokens as f64 / denom,
        virtual_secs,
        counters,
        aggregate: aggregate.into_slo("all"),
        tenants,
        memory: memory.stats(),
        completion_ids,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_ids_round_trip() {
        for p in SchedPolicy::ALL {
            assert_eq!(SchedPolicy::parse(p.id()), Some(p));
        }
        assert_eq!(SchedPolicy::parse("rr"), Some(SchedPolicy::RoundRobin));
        assert_eq!(
            SchedPolicy::parse("shortest-remaining"),
            Some(SchedPolicy::ShortestRemaining)
        );
        assert_eq!(SchedPolicy::parse("magic"), None);
    }
}
