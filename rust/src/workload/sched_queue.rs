//! Runnable-stream index structures behind the workload scheduler's
//! million-stream drain ([`crate::workload::sched`]).
//!
//! The original drain kept in-flight streams in a `Vec<Stream>` and ran
//! three linear scans per step: `slot_busy.iter().position(..)` to find
//! a free predictor slot, a whole-vector scan for the
//! shortest-remaining-decode pick, and `Vec::remove` (an O(n) shift) on
//! completion — fine at tens of streams, quadratic death at 10⁵–10⁶.
//! This module replaces all three with O(1)-amortized structures keyed
//! by the stable *slot* index the SoA stream state lives at, while
//! reproducing the reference scans' pick order **bit for bit** (pinned
//! by the parity suite in `tests/workload_determinism.rs`):
//!
//! * [`FreeSlots`] — a hierarchical bitmap over slot indices whose
//!   `acquire` returns the MINIMUM free index.  Minimality matters: the
//!   old `position(|b| !*b)` scan also picked the lowest free slot, and
//!   slot choice is observable through per-slot predictor state (a
//!   slot's EAMC grows across the requests it serves), so a LIFO free
//!   list would silently change reports.
//! * [`AdmitRing`] — an intrusive doubly-linked list over in-flight
//!   slots in admission order: O(1) head pick (FCFS), O(1) cursor step
//!   (round-robin), O(1) unlink on completion.  The round-robin cursor
//!   reproduces the reference engine's positional `rr_idx` bookkeeping
//!   exactly — including the subtle past-the-end state where `rr_idx ==
//!   len` and the next admission, not the head, becomes the next pick.
//! * [`RemainingBuckets`] — a bucket queue (calendar with one-token
//!   buckets) keyed by remaining decode tokens, one intrusive FIFO per
//!   bucket plus a min-bucket pointer: O(1) amortized pick for
//!   shortest-remaining-decode.  FIFO order within a bucket equals
//!   admission order, which makes the pick identical to the reference
//!   scan's strict-`<` leftmost-minimum tie-break (proof at
//!   [`RemainingBuckets::step_down`]).
//!
//! [`ReferenceRunnable`] retains the original linear-scan algorithm
//! verbatim behind the same [`RunnableSet`] interface; the drain loop is
//! generic over the two, so "byte-identical pick order" is a property
//! the tests can assert on the whole report, not an argument.

use crate::workload::sched::SchedPolicy;

/// Niche sentinel for the intrusive `u32` links ("no slot").
const NONE: u32 = u32::MAX;

/// What one executed unit of work did to the picked stream — the only
/// scheduling facts the runnable structures need to stay in sync.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum StepOutcome {
    /// The stream prefilled its whole prompt; remaining decode tokens
    /// are unchanged.
    Prefill,
    /// The stream decoded one token and has more remaining.
    Decode,
    /// The stream decoded its last token and leaves the engine.
    Complete,
}

/// The drain loop's view of "who is runnable": slot allocation,
/// admission, policy pick, and post-step bookkeeping.  Implemented by
/// [`IndexedRunnable`] (the O(1) structures above) and
/// [`ReferenceRunnable`] (the original linear scans, kept as the parity
/// target).
pub(crate) trait RunnableSet {
    /// Acquire the lowest free slot index, growing state on demand —
    /// memory stays proportional to the concurrency high-water mark,
    /// never the configured limit.
    fn acquire_slot(&mut self) -> usize;
    /// Admit an already-acquired slot at the back of the admission
    /// order with `decode_tokens` remaining.
    fn admit(&mut self, slot: usize, decode_tokens: usize);
    /// In-flight stream count.
    fn len(&self) -> usize;
    /// Pick the next slot to step under the configured policy.
    /// `decode`/`decoded` are the SoA token columns (remaining =
    /// `decode[slot] - decoded[slot]`); only the reference engine's
    /// shortest-remaining scan reads them.
    fn pick(&mut self, decode: &[u32], decoded: &[u32]) -> usize;
    /// Record what the step did to the picked slot (must be the slot
    /// the last `pick` returned).
    fn stepped(&mut self, slot: usize, outcome: StepOutcome);
}

// ---------------------------------------------------------------------------
// FreeSlots: hierarchical min-index bitmap
// ---------------------------------------------------------------------------

/// Hierarchical bitmap free-slot allocator: `levels[0]` holds one bit
/// per slot (1 = free), `levels[k]` summarizes 64-word groups of
/// `levels[k-1]` (bit set ⇔ child word non-zero), and the top level
/// stays ≤ 64 words.  `acquire` finds the minimum free index by
/// descending `trailing_zeros`, so it replaces the reference engine's
/// `position(|b| !*b)` scan with the SAME choice in O(levels) ≈ O(1)
/// (3 levels cover 2²⁴ slots).
#[derive(Debug, Default)]
pub(crate) struct FreeSlots {
    levels: Vec<Vec<u64>>,
    /// Slots ever created (indices `0..cap`); bits past `cap` are 0.
    cap: usize,
}

impl FreeSlots {
    pub(crate) fn new() -> Self {
        Self {
            levels: vec![Vec::new()],
            cap: 0,
        }
    }

    /// Slot high-water mark (the SoA arrays grow in lock-step).
    #[cfg(test)]
    pub(crate) fn capacity(&self) -> usize {
        self.cap
    }

    /// Lowest free slot, creating a fresh one when every slot is busy.
    pub(crate) fn acquire(&mut self) -> usize {
        if let Some(slot) = self.first_free() {
            self.set_busy(slot);
            return slot;
        }
        // every bit (at every level) is 0, so new words are correctly
        // all-zero summaries and the fresh slot starts busy
        let slot = self.cap;
        self.cap += 1;
        let mut need = slot / 64 + 1;
        let mut lvl = 0;
        loop {
            if self.levels.len() == lvl {
                self.levels.push(Vec::new());
            }
            if self.levels[lvl].len() < need {
                self.levels[lvl].resize(need, 0);
            }
            if self.levels[lvl].len() <= 64 {
                break;
            }
            need = (self.levels[lvl].len() + 63) / 64;
            lvl += 1;
        }
        slot
    }

    /// Mark `slot` free again.
    pub(crate) fn release(&mut self, slot: usize) {
        debug_assert!(slot < self.cap, "release of a never-acquired slot");
        let mut idx = slot;
        for lvl in 0..self.levels.len() {
            let (w, b) = (idx / 64, idx % 64);
            let word = &mut self.levels[lvl][w];
            let was_nonzero = *word != 0;
            *word |= 1u64 << b;
            if was_nonzero {
                return; // ancestors already flag this subtree
            }
            idx = w;
        }
    }

    fn set_busy(&mut self, slot: usize) {
        let mut idx = slot;
        for lvl in 0..self.levels.len() {
            let (w, b) = (idx / 64, idx % 64);
            let word = &mut self.levels[lvl][w];
            *word &= !(1u64 << b);
            if *word != 0 {
                return; // subtree still holds a free bit
            }
            idx = w;
        }
    }

    fn first_free(&self) -> Option<usize> {
        let top = self.levels.last()?;
        let w0 = top.iter().position(|&w| w != 0)?;
        let mut idx = w0 * 64 + top[w0].trailing_zeros() as usize;
        for lvl in (0..self.levels.len() - 1).rev() {
            let word = self.levels[lvl][idx];
            debug_assert_ne!(word, 0, "summary bit set over an empty word");
            idx = idx * 64 + word.trailing_zeros() as usize;
        }
        Some(idx)
    }
}

// ---------------------------------------------------------------------------
// AdmitRing: intrusive admission-order list + round-robin cursor
// ---------------------------------------------------------------------------

/// Intrusive doubly-linked list over in-flight slots in admission
/// order.  `head` doubles as the FCFS pick; `cursor` carries the
/// round-robin position.
///
/// The cursor models the reference engine's positional `rr_idx`
/// exactly.  The invariant (maintained by every transition below):
/// `cursor == NONE` ⇔ `rr_idx == len` (past the end), otherwise the
/// cursor slot sits at position `rr_idx`.  The trap this encodes: after
/// the tail stream is stepped, `rr_idx == len`, and if new arrivals are
/// admitted before the next pick the reference picks the FIRST NEW
/// arrival (position `old_len`), not the head — so the first
/// `push_back` in the past-the-end state becomes the cursor, and only a
/// pick with the ring still past-the-end wraps to `head`.
#[derive(Debug, Default)]
pub(crate) struct AdmitRing {
    prev: Vec<u32>,
    next: Vec<u32>,
    head: u32,
    tail: u32,
    cursor: u32,
    len: usize,
}

impl AdmitRing {
    pub(crate) fn new() -> Self {
        Self {
            prev: Vec::new(),
            next: Vec::new(),
            head: NONE,
            tail: NONE,
            cursor: NONE,
            len: 0,
        }
    }

    pub(crate) fn len(&self) -> usize {
        self.len
    }

    /// Admission-order head (the FCFS pick); `NONE` when empty.
    pub(crate) fn head(&self) -> u32 {
        self.head
    }

    pub(crate) fn push_back(&mut self, slot: usize) {
        if self.prev.len() <= slot {
            self.prev.resize(slot + 1, NONE);
            self.next.resize(slot + 1, NONE);
        }
        let s = slot as u32;
        self.prev[slot] = self.tail;
        self.next[slot] = NONE;
        if self.tail == NONE {
            self.head = s;
        } else {
            self.next[self.tail as usize] = s;
        }
        self.tail = s;
        if self.cursor == NONE {
            // rr_idx == old len: the first append lands exactly there
            self.cursor = s;
        }
        self.len += 1;
    }

    /// Round-robin pick: the cursor slot, wrapping a past-the-end
    /// cursor to the head (the reference's `if rr_idx >= len { rr_idx =
    /// 0 }`).
    pub(crate) fn rr_pick(&mut self) -> u32 {
        if self.cursor == NONE {
            self.cursor = self.head;
        }
        self.cursor
    }

    /// The picked slot was stepped without completing: advance the
    /// cursor to its successor (`rr_idx = i + 1`, possibly past the
    /// end).
    pub(crate) fn rr_advance(&mut self, slot: usize) {
        self.cursor = self.next[slot];
    }

    /// Unlink a completed slot.  A cursor on the unlinked slot moves to
    /// the successor — positionally, removal at `i == rr_idx` leaves
    /// `rr_idx` pointing at the old successor (the reference's
    /// `rr_idx > i` guard never fires for the picked slot itself).
    pub(crate) fn unlink(&mut self, slot: usize) {
        let s = slot as u32;
        debug_assert!(self.len > 0);
        if self.cursor == s {
            self.cursor = self.next[slot];
        }
        let (p, n) = (self.prev[slot], self.next[slot]);
        if p == NONE {
            self.head = n;
        } else {
            self.next[p as usize] = n;
        }
        if n == NONE {
            self.tail = p;
        } else {
            self.prev[n as usize] = p;
        }
        self.prev[slot] = NONE;
        self.next[slot] = NONE;
        self.len -= 1;
    }
}

// ---------------------------------------------------------------------------
// RemainingBuckets: calendar queue over remaining decode tokens
// ---------------------------------------------------------------------------

/// Bucket queue for shortest-remaining-decode: one intrusive FIFO per
/// remaining-token count (a calendar with one-token-wide buckets — the
/// key space is bounded by the longest decode length, so no wider
/// bucket or hierarchical wheel is needed), plus a lazily-advanced
/// min-bucket pointer.
///
/// The reference scan picks the leftmost (earliest-admitted) stream of
/// minimal remaining via its strict `<` comparison; here that is the
/// head of the minimum bucket, because FIFO order within every bucket
/// is admission order — see [`RemainingBuckets::step_down`] for why
/// move-downs can never violate that.
#[derive(Debug, Default)]
pub(crate) struct RemainingBuckets {
    head: Vec<u32>,
    tail: Vec<u32>,
    /// Per-slot link: next stream in the same bucket.
    next: Vec<u32>,
    /// Lowest possibly-occupied bucket; may trail below the true
    /// minimum (advanced lazily in [`Self::pick_min`]), never above it.
    min_r: usize,
    len: usize,
}

impl RemainingBuckets {
    pub(crate) fn new() -> Self {
        Self::default()
    }

    /// Append `slot` to bucket `remaining` (called in admission order).
    pub(crate) fn push(&mut self, slot: usize, remaining: usize) {
        if self.head.len() <= remaining {
            self.head.resize(remaining + 1, NONE);
            self.tail.resize(remaining + 1, NONE);
        }
        if self.next.len() <= slot {
            self.next.resize(slot + 1, NONE);
        }
        let s = slot as u32;
        self.next[slot] = NONE;
        if self.head[remaining] == NONE {
            self.head[remaining] = s;
        } else {
            self.next[self.tail[remaining] as usize] = s;
        }
        self.tail[remaining] = s;
        if remaining < self.min_r {
            self.min_r = remaining;
        }
        self.len += 1;
    }

    /// Earliest-admitted slot among those with minimal remaining
    /// tokens.  Amortized O(1): `min_r` only climbs past buckets that
    /// some earlier push or step-down dropped it below.
    pub(crate) fn pick_min(&mut self) -> u32 {
        debug_assert!(self.len > 0, "pick on an empty bucket queue");
        while self.head[self.min_r] == NONE {
            self.min_r += 1;
        }
        self.head[self.min_r]
    }

    /// The picked slot (head of the minimum bucket) decoded one token:
    /// move it down one bucket.
    ///
    /// The destination bucket is always EMPTY: the moving stream had
    /// the globally minimal remaining `r`, so no stream can already sit
    /// at `r - 1` — hence the mover becomes head and tail at once and
    /// FIFO-equals-admission-order is preserved (later arrivals into
    /// that bucket, whether fresh admissions or future move-downs, are
    /// strictly later in admission order than everything in flight).
    pub(crate) fn step_down(&mut self, slot: usize) {
        let s = slot as u32;
        debug_assert_eq!(self.head[self.min_r], s, "step of a non-minimum stream");
        let n = self.next[slot];
        self.head[self.min_r] = n;
        if n == NONE {
            self.tail[self.min_r] = NONE;
        }
        let r = self.min_r - 1;
        debug_assert_eq!(self.head[r], NONE, "occupied bucket below the global minimum");
        self.next[slot] = NONE;
        self.head[r] = s;
        self.tail[r] = s;
        self.min_r = r;
    }

    /// Remove the completed slot (the picked minimum-bucket head).
    pub(crate) fn pop_min(&mut self, slot: usize) {
        debug_assert_eq!(self.head[self.min_r], slot as u32);
        let n = self.next[slot];
        self.head[self.min_r] = n;
        if n == NONE {
            self.tail[self.min_r] = NONE;
        }
        self.next[slot] = NONE;
        self.len -= 1;
    }
}

// ---------------------------------------------------------------------------
// The two engines
// ---------------------------------------------------------------------------

/// The O(1)-amortized runnable set: [`FreeSlots`] + [`AdmitRing`] +
/// (under shortest-remaining-decode) [`RemainingBuckets`].
#[derive(Debug)]
pub(crate) struct IndexedRunnable {
    policy: SchedPolicy,
    free: FreeSlots,
    ring: AdmitRing,
    buckets: RemainingBuckets,
}

impl IndexedRunnable {
    pub(crate) fn new(policy: SchedPolicy) -> Self {
        Self {
            policy,
            free: FreeSlots::new(),
            ring: AdmitRing::new(),
            buckets: RemainingBuckets::new(),
        }
    }

    fn srd(&self) -> bool {
        self.policy == SchedPolicy::ShortestRemaining
    }
}

impl RunnableSet for IndexedRunnable {
    fn acquire_slot(&mut self) -> usize {
        self.free.acquire()
    }

    fn admit(&mut self, slot: usize, decode_tokens: usize) {
        self.ring.push_back(slot);
        if self.srd() {
            self.buckets.push(slot, decode_tokens);
        }
    }

    fn len(&self) -> usize {
        self.ring.len()
    }

    fn pick(&mut self, _decode: &[u32], _decoded: &[u32]) -> usize {
        let s = match self.policy {
            SchedPolicy::Fcfs => self.ring.head(),
            SchedPolicy::RoundRobin => self.ring.rr_pick(),
            SchedPolicy::ShortestRemaining => self.buckets.pick_min(),
        };
        debug_assert_ne!(s, NONE, "pick on an empty runnable set");
        s as usize
    }

    fn stepped(&mut self, slot: usize, outcome: StepOutcome) {
        match outcome {
            StepOutcome::Prefill | StepOutcome::Decode => {
                if self.policy == SchedPolicy::RoundRobin {
                    self.ring.rr_advance(slot);
                }
                if outcome == StepOutcome::Decode && self.srd() {
                    self.buckets.step_down(slot);
                }
            }
            StepOutcome::Complete => {
                if self.srd() {
                    self.buckets.pop_min(slot);
                }
                self.ring.unlink(slot);
                self.free.release(slot);
            }
        }
    }
}

/// The original linear-scan algorithm, verbatim, behind the
/// [`RunnableSet`] interface: a `Vec` of slots in admission order, the
/// positional `rr_idx` cursor with its decrement-on-remove dance, a
/// whole-vector shortest-remaining scan, and a linear free-slot scan.
/// Kept as the byte-parity target and selectable via
/// [`crate::workload::SchedEngine::LinearScan`].
#[derive(Debug)]
pub(crate) struct ReferenceRunnable {
    policy: SchedPolicy,
    busy: Vec<bool>,
    /// In-flight slots in admission order (the old `Vec<Stream>`).
    order: Vec<usize>,
    rr_idx: usize,
    picked_pos: usize,
}

impl ReferenceRunnable {
    pub(crate) fn new(policy: SchedPolicy) -> Self {
        Self {
            policy,
            busy: Vec::new(),
            order: Vec::new(),
            rr_idx: 0,
            picked_pos: 0,
        }
    }
}

impl RunnableSet for ReferenceRunnable {
    fn acquire_slot(&mut self) -> usize {
        // the original `slot_busy.iter().position(|b| !*b)`, grown on
        // demand instead of pre-sized to the concurrency limit
        match self.busy.iter().position(|b| !*b) {
            Some(slot) => {
                self.busy[slot] = true;
                slot
            }
            None => {
                self.busy.push(true);
                self.busy.len() - 1
            }
        }
    }

    fn admit(&mut self, slot: usize, _decode_tokens: usize) {
        self.order.push(slot);
    }

    fn len(&self) -> usize {
        self.order.len()
    }

    fn pick(&mut self, decode: &[u32], decoded: &[u32]) -> usize {
        let i = match self.policy {
            SchedPolicy::Fcfs => 0,
            SchedPolicy::RoundRobin => {
                if self.rr_idx >= self.order.len() {
                    self.rr_idx = 0;
                }
                self.rr_idx
            }
            SchedPolicy::ShortestRemaining => {
                let rem = |pos: usize| {
                    let s = self.order[pos];
                    decode[s] - decoded[s]
                };
                let mut best = 0usize;
                for j in 1..self.order.len() {
                    if rem(j) < rem(best) {
                        best = j;
                    }
                }
                best
            }
        };
        self.picked_pos = i;
        self.order[i]
    }

    fn stepped(&mut self, slot: usize, outcome: StepOutcome) {
        let i = self.picked_pos;
        debug_assert_eq!(self.order[i], slot);
        match outcome {
            StepOutcome::Complete => {
                self.order.remove(i);
                self.busy[slot] = false;
                if self.rr_idx > i {
                    self.rr_idx -= 1; // keep the cursor on the same logical stream
                }
            }
            StepOutcome::Prefill | StepOutcome::Decode => {
                if self.policy == SchedPolicy::RoundRobin {
                    self.rr_idx = i + 1; // advance past the stream just stepped
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// `FreeSlots::acquire` must equal the naive lowest-free scan under
    /// random churn, across level boundaries (> 64² slots).
    #[test]
    fn free_slots_match_naive_min_scan() {
        let mut fs = FreeSlots::new();
        let mut naive: Vec<bool> = Vec::new(); // true = busy
        let mut rng = Rng::new(42);
        let mut held: Vec<usize> = Vec::new();
        for step in 0..30_000 {
            let acquire = held.is_empty() || rng.below(100) < 55;
            if acquire {
                let want = match naive.iter().position(|b| !*b) {
                    Some(i) => i,
                    None => {
                        naive.push(false);
                        naive.len() - 1
                    }
                };
                naive[want] = true;
                let got = fs.acquire();
                assert_eq!(got, want, "step {step}");
                held.push(got);
            } else {
                let k = rng.below(held.len());
                let slot = held.swap_remove(k);
                naive[slot] = false;
                fs.release(slot);
            }
        }
        assert!(fs.capacity() > 64, "churn never crossed a word boundary");
        assert_eq!(fs.capacity(), naive.len());
    }

    #[test]
    fn free_slots_scale_past_two_levels() {
        let mut fs = FreeSlots::new();
        let n = 70_000; // > 64² ⇒ three levels
        for i in 0..n {
            assert_eq!(fs.acquire(), i);
        }
        assert!(fs.levels.len() >= 3);
        fs.release(69_999);
        fs.release(1_234);
        fs.release(0);
        assert_eq!(fs.acquire(), 0);
        assert_eq!(fs.acquire(), 1_234);
        assert_eq!(fs.acquire(), 69_999);
        assert_eq!(fs.acquire(), n, "exhausted bitmap must grow");
    }

    /// Drive both engines with an identical random pick/step/admit tape
    /// and require identical picks — the structure-level face of the
    /// report-level parity suite.
    #[test]
    fn engines_pick_identically_under_random_churn() {
        for policy in SchedPolicy::ALL {
            let mut a = IndexedRunnable::new(policy);
            let mut b = ReferenceRunnable::new(policy);
            let mut rng = Rng::new(7 + policy.id().len() as u64);
            // SoA token columns, grown as slots appear
            let mut decode: Vec<u32> = Vec::new();
            let mut decoded: Vec<u32> = Vec::new();
            let mut prefilled: Vec<bool> = Vec::new();
            let mut next_admissions = 0usize;
            for step in 0..20_000 {
                let admit = a.len() == 0 || (next_admissions < 3_000 && rng.below(100) < 30);
                if admit {
                    next_admissions += 1;
                    let sa = a.acquire_slot();
                    let sb = b.acquire_slot();
                    assert_eq!(sa, sb, "{policy:?} slot choice diverged at {step}");
                    if decode.len() <= sa {
                        decode.resize(sa + 1, 0);
                        decoded.resize(sa + 1, 0);
                        prefilled.resize(sa + 1, false);
                    }
                    decode[sa] = 1 + rng.below(9) as u32;
                    decoded[sa] = 0;
                    prefilled[sa] = false;
                    a.admit(sa, decode[sa] as usize);
                    b.admit(sb, decode[sa] as usize);
                    continue;
                }
                let pa = a.pick(&decode, &decoded);
                let pb = b.pick(&decode, &decoded);
                assert_eq!(pa, pb, "{policy:?} pick diverged at step {step}");
                let outcome = if !prefilled[pa] {
                    prefilled[pa] = true;
                    StepOutcome::Prefill
                } else {
                    decoded[pa] += 1;
                    if decoded[pa] == decode[pa] {
                        StepOutcome::Complete
                    } else {
                        StepOutcome::Decode
                    }
                };
                a.stepped(pa, outcome);
                b.stepped(pb, outcome);
                assert_eq!(a.len(), b.len());
            }
        }
    }

    /// The round-robin past-the-end trap in isolation: step the tail
    /// (cursor past the end), admit a newcomer, and the next pick must
    /// be the NEWCOMER (positional `rr_idx == old_len`), not the head a
    /// naive circular cursor would wrap to.
    #[test]
    fn rr_cursor_past_the_end_picks_the_new_arrival() {
        let decode = vec![10u32; 8];
        let decoded = vec![0u32; 8];
        let mut q = IndexedRunnable::new(SchedPolicy::RoundRobin);
        let s0 = q.acquire_slot();
        q.admit(s0, 10);
        let s1 = q.acquire_slot();
        q.admit(s1, 10);
        assert_eq!(q.pick(&decode, &decoded), s0);
        q.stepped(s0, StepOutcome::Decode);
        assert_eq!(q.pick(&decode, &decoded), s1);
        q.stepped(s1, StepOutcome::Decode); // tail stepped: cursor past the end
        let s2 = q.acquire_slot();
        q.admit(s2, 10); // admitted while past the end
        assert_eq!(q.pick(&decode, &decoded), s2, "must pick the new arrival");
        q.stepped(s2, StepOutcome::Decode);
        assert_eq!(q.pick(&decode, &decoded), s0, "then wrap to the head");
    }

    /// Completion at the cursor: the cursor must land on the successor,
    /// matching the reference's `rr_idx`-stays-at-`i` semantics.
    #[test]
    fn rr_cursor_survives_completion_interleave() {
        let decode = vec![1u32, 5, 5];
        let mut decoded = vec![0u32; 3];
        let mut q = IndexedRunnable::new(SchedPolicy::RoundRobin);
        for s in 0..3 {
            let got = q.acquire_slot();
            assert_eq!(got, s);
            q.admit(got, decode[s] as usize);
        }
        assert_eq!(q.pick(&decode, &decoded), 0);
        decoded[0] = 1;
        q.stepped(0, StepOutcome::Complete); // cursor was on 0 → successor 1
        assert_eq!(q.pick(&decode, &decoded), 1);
        q.stepped(1, StepOutcome::Decode);
        assert_eq!(q.pick(&decode, &decoded), 2);
        q.stepped(2, StepOutcome::Decode);
        // freed slot 0 is the minimum free index again
        assert_eq!(q.acquire_slot(), 0);
    }

    /// Shortest-remaining: a move-down always lands in an empty bucket
    /// and the head of the minimum bucket is the earliest-admitted
    /// minimum (asserted indirectly via the parity churn above; here a
    /// hand trace with an admission tie).
    #[test]
    fn srd_buckets_prefer_earliest_admitted_on_ties() {
        let decode = vec![3u32, 2, 2];
        let mut decoded = vec![0u32; 3];
        let mut q = IndexedRunnable::new(SchedPolicy::ShortestRemaining);
        for s in 0..3 {
            q.acquire_slot();
            q.admit(s, decode[s] as usize);
        }
        // slots 1 and 2 tie at remaining 2: earliest admitted (1) wins
        assert_eq!(q.pick(&decode, &decoded), 1);
        decoded[1] = 1;
        q.stepped(1, StepOutcome::Decode); // now alone at remaining 1
        assert_eq!(q.pick(&decode, &decoded), 1);
        decoded[1] = 2;
        q.stepped(1, StepOutcome::Complete);
        assert_eq!(q.pick(&decode, &decoded), 2);
        decoded[2] = 1;
        q.stepped(2, StepOutcome::Decode);
        assert_eq!(q.pick(&decode, &decoded), 2);
        decoded[2] = 2;
        q.stepped(2, StepOutcome::Complete);
        assert_eq!(q.pick(&decode, &decoded), 0);
    }
}
