//! SLO metrics for the multi-tenant simulator: per-tenant and aggregate
//! TTFT / TBT / request-latency percentiles (virtual-time twins of the
//! serving coordinator's wall-clock [`crate::metrics::LatencyReport`]s),
//! hit-rate-under-contention, and the deterministic JSON encoding the CI
//! perf gate diffs against its golden file.

use crate::cache::CacheStats;
use crate::memory::MemoryStats;
use crate::metrics::LatencyReport;
use crate::obs::Hist;
use crate::util::json::Json;
use crate::workload::sched::SchedCounters;

/// Raw per-tenant sample accumulation while the simulator runs.
/// Latency series go into bounded-memory [`Hist`]s (~12.8 KB each,
/// independent of stream count) instead of per-sample vectors, so the
/// accumulator stays flat at the ROADMAP's 10⁵–10⁶-stream scale.
#[derive(Debug, Clone, Default)]
pub struct TenantAcc {
    /// Arrival → first decode token (µs); includes queueing + prefill.
    pub ttft: Hist,
    /// Time between consecutive decode tokens of one stream (µs); under
    /// interleaving this is where contention shows first.
    pub tbt: Hist,
    /// Arrival → request completion (µs).
    pub latency: Hist,
    /// Arrival → admission (µs): modeled queueing delay.
    pub queue: Hist,
    /// Decode-phase hit/miss/prediction counters against the shared
    /// expert memory.
    pub cache: CacheStats,
    pub completed: u64,
    pub tokens: u64,
}

impl TenantAcc {
    pub fn merge(&mut self, other: &TenantAcc) {
        self.ttft.merge(&other.ttft);
        self.tbt.merge(&other.tbt);
        self.latency.merge(&other.latency);
        self.queue.merge(&other.queue);
        self.cache.merge(&other.cache);
        self.completed += other.completed;
        self.tokens += other.tokens;
    }

    /// Collapse the histograms into percentile reports.
    pub fn into_slo(self, name: &str) -> TenantSlo {
        TenantSlo {
            name: name.to_string(),
            completed: self.completed,
            tokens: self.tokens,
            ttft: LatencyReport::from_hist(&self.ttft),
            tbt: LatencyReport::from_hist(&self.tbt),
            request_latency: LatencyReport::from_hist(&self.latency),
            queue_delay: LatencyReport::from_hist(&self.queue),
            cache: self.cache,
        }
    }
}

/// One tenant's (or the aggregate's) SLO outcome.
#[derive(Debug, Clone)]
pub struct TenantSlo {
    pub name: String,
    pub completed: u64,
    pub tokens: u64,
    pub ttft: LatencyReport,
    pub tbt: LatencyReport,
    pub request_latency: LatencyReport,
    pub queue_delay: LatencyReport,
    pub cache: CacheStats,
}

/// Everything one simulator run produced.
#[derive(Debug, Clone)]
pub struct WorkloadReport {
    /// Scheduler policy id ("fcfs" | "round-robin" | "srd").
    pub policy: String,
    /// Residency backend name ("flat" | "tiered").
    pub backend: String,
    /// Predictor config id driving prefetch.
    pub predictor: String,
    /// Mean offered load of the generated schedule (requests/second).
    pub offered_rps: f64,
    /// Completions per virtual second (== offered below saturation).
    pub completed_rps: f64,
    /// Decode tokens per virtual second.
    pub tokens_per_sec: f64,
    /// Virtual clock at drain (seconds).
    pub virtual_secs: f64,
    pub counters: SchedCounters,
    /// Cross-tenant aggregate (name "all").
    pub aggregate: TenantSlo,
    pub tenants: Vec<TenantSlo>,
    /// Shared-memory cost/residency snapshot at drain.
    pub memory: MemoryStats,
    /// Request ids in completion order (scheduler-ordering tests; not
    /// part of the JSON encoding).  Capped at
    /// `WorkloadConfig::completion_log_cap` entries so a 10⁶-stream
    /// drain cannot grow it without bound — order checks past the cap
    /// use the O(1) streaming
    /// `SchedCounters::out_of_order_completions` counter.  Empty on
    /// sharded drains (per-shard completion orders do not interleave
    /// into one global order).
    pub completion_ids: Vec<u64>,
}

fn latency_json(r: &LatencyReport) -> Json {
    Json::obj(vec![
        ("count", Json::num(r.count as f64)),
        ("mean_us", Json::num(r.mean_us)),
        ("p50_us", Json::num(r.p50_us)),
        ("p95_us", Json::num(r.p95_us)),
        ("p99_us", Json::num(r.p99_us)),
        ("max_us", Json::num(r.max_us)),
    ])
}

fn tenant_json(t: &TenantSlo) -> Json {
    Json::obj(vec![
        ("name", Json::str(&t.name)),
        ("completed", Json::num(t.completed as f64)),
        ("tokens", Json::num(t.tokens as f64)),
        ("hits", Json::num(t.cache.hits as f64)),
        ("misses", Json::num(t.cache.misses as f64)),
        ("hit_rate", Json::num(t.cache.hit_rate())),
        ("prediction_hits", Json::num(t.cache.prediction_hits as f64)),
        ("prediction_total", Json::num(t.cache.prediction_total as f64)),
        ("prefetches", Json::num(t.cache.prefetches as f64)),
        ("wasted_prefetches", Json::num(t.cache.wasted_prefetches as f64)),
        ("transfer_us", Json::num(t.cache.transfer_us)),
        ("ttft", latency_json(&t.ttft)),
        ("tbt", latency_json(&t.tbt)),
        ("request_latency", latency_json(&t.request_latency)),
        ("queue_delay", latency_json(&t.queue_delay)),
    ])
}

/// Memory-cost block of the report: the flat demand/prefetch/stall
/// marks, plus a "net" sub-object on cluster backends (retries and
/// degraded fetches included, so chaos runs are auditable straight from
/// the metrics file).  Non-cluster backends omit the key entirely.
fn memory_json(m: &MemoryStats) -> Json {
    let mut fields = vec![
        ("demand_us", Json::num(m.demand_us)),
        ("prefetch_us", Json::num(m.prefetch_us)),
        ("stall_us", Json::num(m.stall_us)),
    ];
    if let Some(n) = &m.net {
        fields.push((
            "net",
            Json::obj(vec![
                ("remote_lookups", Json::num(n.remote_lookups as f64)),
                ("remote_hits", Json::num(n.remote_hits as f64)),
                ("failovers", Json::num(n.failovers as f64)),
                ("retries", Json::num(n.retries as f64)),
                ("degraded_fetches", Json::num(n.degraded_fetches as f64)),
                ("wire_us", Json::num(n.wire_us)),
                ("promotion_us", Json::num(n.promotion_us)),
                ("timeout_us", Json::num(n.timeout_us)),
                ("backoff_us", Json::num(n.backoff_us)),
            ]),
        ));
    }
    Json::obj(fields)
}

/// Deterministic JSON encoding of a report: integer-valued floats print
/// as integers, object keys are sorted (BTreeMap), and every number
/// comes out of the same seeded virtual-time arithmetic — so two runs of
/// the same workload serialize to byte-identical strings, which is the
/// property the CI perf gate builds on.
pub fn report_json(r: &WorkloadReport) -> Json {
    let c = &r.counters;
    Json::obj(vec![
        ("policy", Json::str(&r.policy)),
        ("backend", Json::str(&r.backend)),
        ("predictor", Json::str(&r.predictor)),
        ("offered_rps", Json::num(r.offered_rps)),
        ("completed_rps", Json::num(r.completed_rps)),
        ("tokens_per_sec", Json::num(r.tokens_per_sec)),
        ("virtual_secs", Json::num(r.virtual_secs)),
        (
            "counters",
            Json::obj(vec![
                ("steps", Json::num(c.steps as f64)),
                ("prefill_steps", Json::num(c.prefill_steps as f64)),
                ("admissions", Json::num(c.admissions as f64)),
                ("completions", Json::num(c.completions as f64)),
                ("max_inflight", Json::num(c.max_inflight as f64)),
                ("max_queue_depth", Json::num(c.max_queue_depth as f64)),
                ("busy_us", Json::num(c.busy_us)),
                ("idle_us", Json::num(c.idle_us)),
                (
                    "idle_while_runnable",
                    Json::num(c.idle_while_runnable as f64),
                ),
                (
                    "repeat_pick_with_waiters",
                    Json::num(c.repeat_pick_with_waiters as f64),
                ),
            ]),
        ),
        ("memory", memory_json(&r.memory)),
        ("aggregate", tenant_json(&r.aggregate)),
        (
            "tenants",
            Json::Arr(r.tenants.iter().map(tenant_json).collect()),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_combines_histograms_and_sums() {
        let mut a = TenantAcc {
            completed: 2,
            tokens: 10,
            ..Default::default()
        };
        a.ttft.record(1.0);
        let mut b = TenantAcc {
            completed: 1,
            tokens: 5,
            ..Default::default()
        };
        b.ttft.record(3.0);
        b.ttft.record(4.0);
        a.merge(&b);
        assert_eq!(a.ttft.count(), 3);
        assert_eq!(a.ttft.min_us(), 1.0);
        assert_eq!(a.ttft.max_us(), 4.0);
        assert!((a.ttft.sum_us() - 8.0).abs() < 1e-9);
        assert_eq!(a.completed, 3);
        assert_eq!(a.tokens, 15);
    }

    #[test]
    fn into_slo_builds_percentiles() {
        let mut acc = TenantAcc {
            completed: 100,
            tokens: 400,
            ..Default::default()
        };
        for x in 1..=100 {
            acc.ttft.record(x as f64);
        }
        let slo = acc.into_slo("t0");
        assert_eq!(slo.name, "t0");
        assert_eq!(slo.ttft.count, 100);
        // exact nearest-rank p50 is 51; histogram within 2%
        assert!((slo.ttft.p50_us - 51.0).abs() <= 51.0 * 0.02 + 1e-9);
        assert_eq!(slo.ttft.max_us, 100.0);
        // empty series stay well-defined
        assert_eq!(slo.tbt.count, 0);
        assert_eq!(slo.tbt.p95_us, 0.0);
    }
}
