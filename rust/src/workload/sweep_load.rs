//! `sweep_load` — Fig 7 extended into throughput–latency curves: a
//! (scheduler policy × backend × predictor × offered load × cache
//! fraction) grid, each point one full multi-tenant drain, fanned out
//! over the same scoped worker threads as the Fig-7 capacity sweep
//! (`util::parallel::parallel_map`, index-keyed write-back, bit-identical
//! to a serial run).

use crate::cluster::{self, ClusterConfig};
use crate::config::{CacheConfig, EamConfig, SimConfig, TierConfig, WorkloadConfig};
use crate::memory::{self, ExpertMemory};
use crate::obs::ObsSink;
use crate::predictor::{PredictorKind, TracePredictions};
use crate::trace::{CompiledCorpus, PromptTrace};
use crate::util::parallel::{parallel_map, sweep_threads};
use crate::workload::profile::{Schedule, WorkloadSpec};
use crate::workload::sched::{run_workload_obs, run_workload_sharded, SchedPolicy, WorkloadInputs};
use crate::workload::slo::WorkloadReport;
use crate::Result;

/// Residency backend axis of the load sweep.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    Flat,
    Tiered,
    /// K-node edge cluster ([`crate::cluster`]): flat per-node caches
    /// sharded by [`LoadSweepInputs::cluster_base`].  Opt-in only — not
    /// part of [`Backend::ALL`], so default grids (and the golden
    /// contention bench pinned to them) are unchanged.
    Cluster,
}

impl Backend {
    /// The default sweep axis.  Deliberately excludes [`Backend::Cluster`]
    /// (select it explicitly, e.g. `serve-sim --backends cluster`).
    pub const ALL: [Backend; 2] = [Backend::Flat, Backend::Tiered];

    pub fn id(&self) -> &'static str {
        match self {
            Backend::Flat => "flat",
            Backend::Tiered => "tiered",
            Backend::Cluster => "cluster",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "flat" => Some(Backend::Flat),
            "tiered" => Some(Backend::Tiered),
            "cluster" => Some(Backend::Cluster),
            _ => None,
        }
    }
}

/// Everything the grid shares.
///
/// Generic over the [`crate::util::ExpertSet`] word width `N` (default 1
/// = up to 64 experts).
pub struct LoadSweepInputs<'a, const N: usize = 1> {
    pub spec: &'a WorkloadSpec,
    pub pools: &'a [Vec<PromptTrace>],
    pub fit_traces: &'a [PromptTrace],
    /// Precomputed learned predictions per tenant pool (parallel to
    /// `pools`; required iff `kinds` includes `Learned`) — the paper's
    /// own predictor on the multi-tenant curves.
    pub learned: Option<&'a [Vec<TracePredictions<N>>]>,
    /// Policy field is ignored — the policy is a grid axis.
    pub workload: &'a WorkloadConfig,
    pub sim: &'a SimConfig,
    pub eam: &'a EamConfig,
    pub n_layers: usize,
    pub n_experts: usize,
    /// Base hierarchy for `Backend::Tiered` points; its GPU tier is
    /// resized per cache fraction, host/SSD stay as configured.
    pub tier_base: &'a TierConfig,
    /// Topology for `Backend::Cluster` points (node count, placement,
    /// link, faults); each node's flat cache gets a `1/nodes` share of
    /// the swept capacity.  `None` falls back to the 1-node loopback
    /// cluster (byte-identical to `Backend::Flat`).
    pub cluster_base: Option<&'a ClusterConfig>,
    /// Shard-then-merge fan-out per grid point
    /// ([`run_workload_sharded`]): tenants are partitioned across this
    /// many replica engines (each with the point's full memory
    /// capacity) and drained in parallel, accumulators merged in
    /// deterministic shard-index order.  `0`/`1` = the single-engine
    /// drain.  Traced re-runs (`run_point_obs` with an active sink)
    /// should stay at 1 — shard engines drain with no-op sinks.
    pub engine_shards: usize,
}

/// One grid point's outcome.
#[derive(Debug, Clone)]
pub struct LoadPoint {
    pub policy: SchedPolicy,
    pub backend: Backend,
    pub predictor: PredictorKind,
    pub load_mult: f64,
    pub cache_frac: f64,
    pub report: WorkloadReport,
}

/// Grid job: the load axis carries an index into the pre-generated
/// per-load (spec, schedule) table — generation depends only on the
/// load multiplier, so regenerating it per point would be pure waste.
type GridJob = (SchedPolicy, Backend, PredictorKind, usize, f64);

/// Build one grid point's memory backend — shared by the single-engine
/// drain and (called once per shard, inside the shard's worker thread)
/// the shard-then-merge path, so every replica prices capacity with the
/// exact same rounding.
fn build_backend_memory<const N: usize>(
    inputs: &LoadSweepInputs<'_, N>,
    backend: Backend,
    cache_frac: f64,
) -> Result<Box<dyn ExpertMemory<N>>> {
    let total = inputs.n_layers * inputs.n_experts;
    let cap = ((total as f64 * cache_frac).round() as usize).max(1);
    // DMA hides under one layer's share of the token compute, the same
    // coupling the serving engine uses (CacheConfig::overlap_per_layer)
    let overlap_us = inputs.workload.token_compute_us / inputs.n_layers.max(1) as f64;
    match backend {
        Backend::Flat => memory::build::<N>(
            "lru",
            &CacheConfig::default().with_capacity(cap),
            None,
            inputs.sim,
            inputs.n_experts,
            overlap_us,
        ),
        Backend::Tiered => {
            let cfg = inputs.tier_base.clone().with_gpu_capacity(cap);
            memory::build::<N>(
                "lru",
                &CacheConfig::default(),
                Some(&cfg),
                inputs.sim,
                inputs.n_experts,
                overlap_us,
            )
        }
        Backend::Cluster => {
            let fallback = ClusterConfig::default();
            let cfg = inputs.cluster_base.unwrap_or(&fallback);
            // fixed per-device budget: the swept capacity is the
            // aggregate, each node holds a 1/k share (same rounding as
            // the flat arm at k = 1)
            let cap_node = ((total as f64 * cache_frac / cfg.nodes as f64).round() as usize).max(1);
            cluster::build::<N>(
                cfg,
                "lru",
                &CacheConfig::default().with_capacity(cap_node),
                None,
                inputs.sim,
                inputs.n_experts,
                overlap_us,
            )
        }
    }
}

fn run_load_point<const N: usize>(
    inputs: &LoadSweepInputs<'_, N>,
    compiled_pools: &[CompiledCorpus<N>],
    loaded: &[(f64, WorkloadSpec, Schedule)],
    job: &GridJob,
    obs: &ObsSink,
) -> Result<LoadPoint> {
    let &(policy, backend, kind, load_idx, cache_frac) = job;
    let (load_mult, ref spec, ref schedule) = loaded[load_idx];

    let mut wcfg = inputs.workload.clone();
    wcfg.policy = policy.id().to_string();
    let winp = WorkloadInputs {
        spec,
        schedule,
        pools: inputs.pools,
        fit_traces: inputs.fit_traces,
        learned: inputs.learned,
        cfg: &wcfg,
        sim: inputs.sim,
        eam: inputs.eam,
        n_layers: inputs.n_layers,
        n_experts: inputs.n_experts,
    };
    let shards = inputs.engine_shards.max(1);
    let report = if shards > 1 {
        let build = || build_backend_memory(inputs, backend, cache_frac);
        run_workload_sharded(&winp, kind, &build, compiled_pools, shards, sweep_threads())?
    } else {
        let mem = build_backend_memory(inputs, backend, cache_frac)?;
        run_workload_obs(&winp, kind, mem, compiled_pools, obs)?
    };
    Ok(LoadPoint {
        policy,
        backend,
        predictor: kind,
        load_mult,
        cache_frac,
        report,
    })
}

/// Re-run ONE grid point with an observability sink attached — the
/// traced-run path behind `--trace-out`/`--metrics-out`.  Generates the
/// point's (spec, schedule) and compiles the tenant pools inline, so
/// callers that already finished a grid sweep don't have to keep those
/// tables alive; the drain itself is byte-identical to the same point
/// inside [`sweep_load`] (same generation seed, same virtual time).
#[allow(clippy::too_many_arguments)]
pub fn run_point_obs<const N: usize>(
    inputs: &LoadSweepInputs<'_, N>,
    policy: SchedPolicy,
    backend: Backend,
    kind: PredictorKind,
    load_mult: f64,
    cache_frac: f64,
    obs: &ObsSink,
) -> Result<LoadPoint> {
    let spec = inputs.spec.with_load(load_mult);
    let schedule = spec.generate(inputs.pools)?;
    let loaded = [(load_mult, spec, schedule)];
    let compiled: Vec<CompiledCorpus<N>> = inputs
        .pools
        .iter()
        .map(|p| CompiledCorpus::compile(p))
        .collect();
    let job: GridJob = (policy, backend, kind, 0, cache_frac);
    run_load_point(inputs, &compiled, &loaded, &job, obs)
}

/// Run the load grid with the default worker count.
pub fn sweep_load<const N: usize>(
    inputs: &LoadSweepInputs<'_, N>,
    policies: &[SchedPolicy],
    backends: &[Backend],
    kinds: &[PredictorKind],
    loads: &[f64],
    fracs: &[f64],
) -> Result<Vec<LoadPoint>> {
    sweep_load_threaded(inputs, policies, backends, kinds, loads, fracs, sweep_threads())
}

/// [`sweep_load`] on an explicit worker count (`1` = serial).  Output is
/// deterministic: identical to the serial run for any count.
#[allow(clippy::too_many_arguments)]
pub fn sweep_load_threaded<const N: usize>(
    inputs: &LoadSweepInputs<'_, N>,
    policies: &[SchedPolicy],
    backends: &[Backend],
    kinds: &[PredictorKind],
    loads: &[f64],
    fracs: &[f64],
    threads: usize,
) -> Result<Vec<LoadPoint>> {
    let mut grid: Vec<GridJob> = Vec::new();
    for &p in policies {
        for &b in backends {
            for &k in kinds {
                for li in 0..loads.len() {
                    for &f in fracs {
                        grid.push((p, b, k, li, f));
                    }
                }
            }
        }
    }
    // one (spec, schedule) per load value — generation is pure in
    // (spec, load_mult), so every grid point at that load shares it
    let loaded: Vec<(f64, WorkloadSpec, Schedule)> = loads
        .iter()
        .map(|&l| {
            let spec = inputs.spec.with_load(l);
            let schedule = spec.generate(inputs.pools)?;
            Ok((l, spec, schedule))
        })
        .collect::<Result<_>>()?;
    // compile every tenant pool once; the Arc-backed tables are shared
    // by all grid workers instead of recompiled per point
    let compiled: Vec<CompiledCorpus<N>> = inputs
        .pools
        .iter()
        .map(|p| CompiledCorpus::compile(p))
        .collect();
    parallel_map(&grid, threads, |job| {
        run_load_point(inputs, &compiled, &loaded, job, &ObsSink::default())
    })
}

/// Throughput–latency CSV over the grid (one row per point; fixed
/// decimal places so the file is stable and diff-friendly).
pub fn load_csv(points: &[LoadPoint]) -> String {
    let mut out = String::from(
        "policy,backend,predictor,load_mult,offered_rps,cache_frac,completed,completed_rps,\
         tokens_per_sec,hit_rate,prediction_hit_rate,p50_ttft_ms,p95_ttft_ms,p50_tbt_ms,\
         p95_tbt_ms,p50_latency_ms,p95_latency_ms,p95_queue_ms,demand_ms,stall_ms,\
         remote_lookups,remote_hits,failovers,retries,degraded_fetches,wire_ms,promo_ms,\
         timeout_ms,backoff_ms\n",
    );
    for p in points {
        let r = &p.report;
        let a = &r.aggregate;
        // non-cluster backends have no NetStats: zero columns keep the
        // schema rectangular across mixed-backend grids
        let net = r.memory.net.clone().unwrap_or_default();
        out.push_str(&format!(
            "{},{},{},{:.3},{:.4},{:.3},{},{:.4},{:.2},{:.4},{:.4},\
             {:.3},{:.3},{:.3},{:.3},{:.3},{:.3},{:.3},{:.3},{:.3},\
             {},{},{},{},{},{:.3},{:.3},{:.3},{:.3}\n",
            p.policy.id(),
            p.backend.id(),
            p.predictor.id(),
            p.load_mult,
            r.offered_rps,
            p.cache_frac,
            a.completed,
            r.completed_rps,
            r.tokens_per_sec,
            a.cache.hit_rate(),
            a.cache.prediction_hit_rate(),
            a.ttft.p50_us / 1e3,
            a.ttft.p95_us / 1e3,
            a.tbt.p50_us / 1e3,
            a.tbt.p95_us / 1e3,
            a.request_latency.p50_us / 1e3,
            a.request_latency.p95_us / 1e3,
            a.queue_delay.p95_us / 1e3,
            r.memory.demand_us / 1e3,
            r.memory.stall_us / 1e3,
            net.remote_lookups,
            net.remote_hits,
            net.failovers,
            net.retries,
            net.degraded_fetches,
            net.wire_us / 1e3,
            net.promotion_us / 1e3,
            net.timeout_us / 1e3,
            net.backoff_us / 1e3,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::profile::{synthetic_fit_pool, synthetic_pools};

    fn fixture() -> (WorkloadSpec, Vec<Vec<PromptTrace>>, Vec<PromptTrace>) {
        let spec = WorkloadSpec::example(2, 11, 4.0);
        let pools = synthetic_pools(&spec, 4, 3, 64);
        let fit = synthetic_fit_pool(&spec, 2, 3, 64);
        (spec, pools, fit)
    }

    #[test]
    fn grid_covers_the_product_and_is_thread_invariant() {
        let (spec, pools, fit) = fixture();
        let wcfg = WorkloadConfig::default();
        let tier = TierConfig::default();
        let sim = SimConfig::default();
        let eam = EamConfig {
            kmeans_clusters: 0,
            ..Default::default()
        };
        let inputs: LoadSweepInputs = LoadSweepInputs {
            spec: &spec,
            pools: &pools,
            fit_traces: &fit,
            learned: None,
            workload: &wcfg,
            sim: &sim,
            eam: &eam,
            n_layers: 3,
            n_experts: 64,
            tier_base: &tier,
            cluster_base: None,
            engine_shards: 1,
        };
        let policies = [SchedPolicy::Fcfs, SchedPolicy::RoundRobin];
        let backends = [Backend::Flat, Backend::Tiered];
        let kinds = [PredictorKind::None];
        let loads = [1.0, 2.0];
        let fracs = [0.1];
        let serial = sweep_load_threaded(
            &inputs, &policies, &backends, &kinds, &loads, &fracs, 1,
        )
        .unwrap();
        assert_eq!(serial.len(), 2 * 2 * 2);
        let par = sweep_load_threaded(
            &inputs, &policies, &backends, &kinds, &loads, &fracs, 4,
        )
        .unwrap();
        for (s, p) in serial.iter().zip(par.iter()) {
            assert_eq!(s.policy, p.policy);
            assert_eq!(s.backend, p.backend);
            assert_eq!(s.report.counters.completions, p.report.counters.completions);
            assert_eq!(s.report.aggregate.cache.hits, p.report.aggregate.cache.hits);
            assert_eq!(
                s.report.virtual_secs.to_bits(),
                p.report.virtual_secs.to_bits()
            );
        }
        // every point drained its whole schedule
        for pt in &serial {
            assert_eq!(
                pt.report.counters.completions,
                pt.report.counters.admissions
            );
            assert_eq!(pt.report.counters.idle_while_runnable, 0);
            assert_eq!(pt.report.backend, pt.backend.id());
        }
        let csv = load_csv(&serial);
        assert_eq!(csv.lines().count(), serial.len() + 1);
        assert!(csv.starts_with("policy,backend,predictor"));
    }

    /// A 1-node loopback cluster backend drains the workload
    /// byte-identically to the flat backend (the workload-level face of
    /// the cluster parity contract; the replay-level suite lives in
    /// `tests/cluster_parity.rs`).
    #[test]
    fn cluster_k1_loopback_matches_flat_backend_exactly() {
        let (spec, pools, fit) = fixture();
        let wcfg = WorkloadConfig::default();
        let tier = TierConfig::default();
        let sim = SimConfig::default();
        let eam = EamConfig {
            kmeans_clusters: 0,
            ..Default::default()
        };
        let k1 = ClusterConfig::default();
        let inputs: LoadSweepInputs = LoadSweepInputs {
            spec: &spec,
            pools: &pools,
            fit_traces: &fit,
            learned: None,
            workload: &wcfg,
            sim: &sim,
            eam: &eam,
            n_layers: 3,
            n_experts: 64,
            tier_base: &tier,
            cluster_base: Some(&k1),
            engine_shards: 1,
        };
        let policies = [SchedPolicy::Fcfs];
        let kinds = [PredictorKind::Eam];
        let loads = [1.5];
        let fracs = [0.1, 0.4];
        let flat = sweep_load_threaded(
            &inputs, &policies, &[Backend::Flat], &kinds, &loads, &fracs, 1,
        )
        .unwrap();
        let cluster = sweep_load_threaded(
            &inputs, &policies, &[Backend::Cluster], &kinds, &loads, &fracs, 1,
        )
        .unwrap();
        assert_eq!(flat.len(), cluster.len());
        for (f, c) in flat.iter().zip(cluster.iter()) {
            assert_eq!(c.backend, Backend::Cluster);
            assert_eq!(c.report.backend, "cluster");
            let (fa, ca) = (&f.report.aggregate, &c.report.aggregate);
            assert_eq!(fa.completed, ca.completed);
            assert_eq!(fa.cache.hits, ca.cache.hits);
            assert_eq!(fa.cache.misses, ca.cache.misses);
            assert_eq!(fa.cache.prefetches, ca.cache.prefetches);
            assert_eq!(
                fa.cache.transfer_us.to_bits(),
                ca.cache.transfer_us.to_bits()
            );
            assert_eq!(
                f.report.virtual_secs.to_bits(),
                c.report.virtual_secs.to_bits()
            );
            assert_eq!(
                f.report.memory.demand_us.to_bits(),
                c.report.memory.demand_us.to_bits()
            );
            assert_eq!(
                f.report.memory.stall_us.to_bits(),
                c.report.memory.stall_us.to_bits()
            );
        }
    }

    /// Tenant-sharded drains ([`LoadSweepInputs::engine_shards`] > 1)
    /// are deterministic (two identical runs produce byte-identical
    /// reports) and conserve the workload: every arrival admits and
    /// completes exactly once across the shard replicas, and per-tenant
    /// completion/token totals match the single-engine drain because a
    /// tenant's streams never cross shards.
    #[test]
    fn sharded_drain_is_deterministic_and_conserves_work() {
        let (spec, pools, fit) = fixture();
        let wcfg = WorkloadConfig::default();
        let tier = TierConfig::default();
        let sim = SimConfig::default();
        let eam = EamConfig {
            kmeans_clusters: 0,
            ..Default::default()
        };
        let mut inputs: LoadSweepInputs = LoadSweepInputs {
            spec: &spec,
            pools: &pools,
            fit_traces: &fit,
            learned: None,
            workload: &wcfg,
            sim: &sim,
            eam: &eam,
            n_layers: 3,
            n_experts: 64,
            tier_base: &tier,
            cluster_base: None,
            engine_shards: 1,
        };
        let point = |inputs: &LoadSweepInputs| {
            run_point_obs(
                inputs,
                SchedPolicy::RoundRobin,
                Backend::Flat,
                PredictorKind::None,
                1.5,
                0.2,
                &ObsSink::default(),
            )
            .unwrap()
        };
        let single = point(&inputs);
        inputs.engine_shards = 2;
        let a = point(&inputs);
        let b = point(&inputs);
        assert_eq!(
            crate::workload::slo::report_json(&a.report).to_json_string(),
            crate::workload::slo::report_json(&b.report).to_json_string(),
            "sharded drain must replay byte-identically"
        );
        let c = &a.report.counters;
        assert_eq!(c.admissions, single.report.counters.admissions);
        assert_eq!(c.completions, c.admissions);
        assert_eq!(c.idle_while_runnable, 0);
        // sharded reports keep no global completion order
        assert!(a.report.completion_ids.is_empty());
        assert_eq!(
            a.report.aggregate.completed,
            single.report.aggregate.completed
        );
        assert_eq!(a.report.aggregate.tokens, single.report.aggregate.tokens);
        for (sa, st) in a.report.tenants.iter().zip(single.report.tenants.iter()) {
            assert_eq!(sa.completed, st.completed);
            assert_eq!(sa.tokens, st.tokens);
        }
    }
}
