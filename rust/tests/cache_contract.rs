//! Cache-policy contract invariants (the `CachePolicy` trait docs) run
//! against EVERY implementation — LRU, LFU, and the offline Belady
//! policy — plus hierarchy invariants for `TieredCache`, the engine's
//! batch-share restore-after-error guarantee, the `ExpertMemory` parity
//! suite (the refactored flat and tiered paths must reproduce the
//! pre-refactor replay loops' numbers exactly), and the `ExpertMemory`
//! trait-level invariant suite run against every backend.

use moe_beyond::cache::{policy, BeladyCache, CachePolicy, CacheStats, LfuCache, LruCache};
use moe_beyond::config::{CacheConfig, SimConfig, TierConfig};
use moe_beyond::coordinator::{ExpertCacheManager, GenStats};
use moe_beyond::memory::{ExpertMemory, FlatMemory, TieredMemory};
use moe_beyond::predictor::{DecodeContext, ExpertPredictor, NoPrefetch, OraclePredictor};
use moe_beyond::sim::SimEngine;
use moe_beyond::tier::{TierCostModel, TierSpec, TierStats, TieredCache};
use moe_beyond::trace::PromptTrace;
use moe_beyond::util::{ExpertSet, Rng};

/// Drive a policy with a random op mix, checking after every op:
/// * `len() <= capacity()`,
/// * `insert` of a resident key only refreshes (no eviction, no growth),
/// * evictions happen only on insert into a full cache, one per insert,
/// * `resident()` agrees with `len()` and `contains()`.
fn check_contract(name: &str, mk: &dyn Fn(usize) -> Box<dyn CachePolicy>, seed: u64) {
    let mut rng = Rng::new(seed);
    for _case in 0..60 {
        let cap = rng.range(1, 10);
        let mut c = mk(cap);
        assert_eq!(c.capacity(), cap, "{name}: capacity mismatch");
        for _ in 0..rng.range(1, 150) {
            let k = rng.below(25) as u32;
            match rng.below(3) {
                0 => {
                    let was_resident = c.contains(k);
                    let len_before = c.len();
                    let evicted = c.insert(k);
                    if was_resident {
                        assert_eq!(evicted, None, "{name}: refresh must not evict");
                        assert_eq!(c.len(), len_before, "{name}: refresh must not grow");
                    } else if len_before == cap {
                        let v = evicted.unwrap_or_else(|| {
                            panic!("{name}: full insert must evict exactly one")
                        });
                        assert_ne!(v, k, "{name}: evicted the key being inserted");
                        assert!(!c.contains(v), "{name}: victim still resident");
                        assert_eq!(c.len(), cap);
                    } else {
                        assert_eq!(evicted, None, "{name}: evicted below capacity");
                        assert_eq!(c.len(), len_before + 1);
                    }
                    assert!(c.contains(k), "{name}: inserted key not resident");
                }
                1 => {
                    let hit = c.touch(k);
                    assert_eq!(hit, c.contains(k), "{name}: touch() vs contains()");
                }
                _ => {
                    let was = c.contains(k);
                    assert_eq!(c.evict(k), was, "{name}: evict() return value");
                    assert!(!c.contains(k), "{name}: evicted key still resident");
                }
            }
            assert!(c.len() <= c.capacity(), "{name}: len exceeds capacity");
            let resident = c.resident();
            assert_eq!(resident.len(), c.len(), "{name}: resident()/len() disagree");
            for &r in &resident {
                assert!(c.contains(r), "{name}: resident key not contained");
            }
        }
    }
}

#[test]
fn lru_satisfies_contract() {
    check_contract("lru", &|cap| Box::new(LruCache::new(cap)), 101);
}

#[test]
fn lfu_satisfies_contract() {
    check_contract("lfu", &|cap| Box::new(LfuCache::new(cap)), 102);
}

#[test]
fn belady_satisfies_contract() {
    // unprimed: every next-use is "never", eviction order is arbitrary
    // but the contract must still hold
    check_contract("belady", &|cap| Box::new(BeladyCache::new(cap)), 103);
    // primed with a future reference string
    check_contract(
        "belady-primed",
        &|cap| {
            let mut c = BeladyCache::new(cap);
            let mut rng = Rng::new(cap as u64);
            let reference: Vec<u32> = (0..200).map(|_| rng.below(25) as u32).collect();
            c.prime(&reference);
            Box::new(c)
        },
        104,
    );
}

/// TieredCache promotion/demotion invariants across random promote
/// streams over a deep hierarchy.
#[test]
fn tiered_cache_promotion_demotion_invariants() {
    let mut rng = Rng::new(105);
    for _case in 0..60 {
        let caps = [rng.range(1, 4), rng.range(1, 6), rng.range(1, 8)];
        let mut c = TieredCache::new(vec![
            Box::new(LruCache::new(caps[0])),
            Box::new(LfuCache::new(caps[1])),
            Box::new(LruCache::new(caps[2])),
        ]);
        let mut total_before = 0usize;
        for _ in 0..rng.range(1, 150) {
            let k = rng.below(30) as u32;
            let was_cold = c.locate(k).is_none();
            let p = c.promote(k);
            assert_eq!(p.found.is_none(), was_cold);
            assert_eq!(c.locate(k), Some(0), "promoted key must be at the top");
            // at most one demotion per tier, strictly downward
            assert!(p.demoted.len() <= 3);
            for d in &p.demoted {
                if let Some(to) = d.to {
                    assert_eq!(to, d.from + 1, "demotion must go one tier down");
                    assert_eq!(c.locate(d.key), Some(to));
                } else {
                    assert!(c.locate(d.key).is_none(), "dropped key still resident");
                }
            }
            // conservation: a promotion adds at most one resident copy
            let total = c.resident_total();
            assert!(total <= total_before + 1);
            total_before = total;
            for (depth, &cap) in caps.iter().enumerate() {
                assert!(c.len_at(depth) <= cap);
            }
        }
    }
}

/// The engine restores the full prefetch window after batch processing
/// even on error paths (`process_batch` restructures around a single
/// restore point); the manager-level restore must therefore be exact
/// and idempotent from any prior share.
#[test]
fn batch_share_restore_after_error_semantics() {
    let mut m: ExpertCacheManager = ExpertCacheManager::new(
        Box::new(LruCache::new(32)),
        CacheConfig::default(),
        &SimConfig::default(),
        64,
        1_000.0,
    )
    .with_prefetch_budget(12);

    // simulate the error path: share set for a batch, "error", restore
    for batch in [2usize, 3, 7, 64] {
        m.set_batch_share(batch);
        assert_eq!(m.effective_prefetch_budget(), (12 / batch).max(1));
        m.set_batch_share(1);
        assert_eq!(
            m.effective_prefetch_budget(),
            12,
            "window not restored after batch={batch}"
        );
    }

    // the budget is the caller's SimConfig knob, not a magic 12
    let fresh: ExpertCacheManager = ExpertCacheManager::new(
        Box::new(LruCache::new(32)),
        CacheConfig::default(),
        &SimConfig::default(),
        64,
        1_000.0,
    );
    assert_eq!(
        fresh.effective_prefetch_budget(),
        SimConfig::default().prefetch_budget
    );
}

/// End-to-end tiered manager: a demand miss on a GPU-full cache demotes
/// into the host tier, and a later access to the demoted expert is
/// served from host (cheap) rather than flash (expensive).
#[test]
fn tiered_manager_promotion_path() {
    let cfg = TierConfig {
        tiers: vec![
            TierSpec::new("gpu", 2, 1.0, 0.0),
            TierSpec::new("host", 8, 100.0, 100.0),
            TierSpec::new("ssd", 64, 1000.0, 0.0),
        ],
        policy: "lru".into(),
    };
    let mut m: ExpertCacheManager =
        ExpertCacheManager::new_tiered(&cfg, &SimConfig::default(), 64, 10_000.0).unwrap();
    let mut stats = GenStats::default();
    m.observe_actual(0, ExpertSet::from_ids([1u8, 2, 3]), &mut stats);
    // expert 1 was demoted to host; touching it again promotes it back
    m.observe_actual(0, ExpertSet::from_ids([1u8]), &mut stats);
    let ts = m.tier_stats().unwrap();
    assert_eq!(ts.cold, 3);
    assert_eq!(ts.served[1], 1);
    assert!(ts.demotions >= 1);
    m.finish(&mut stats);
    // 3 cold reads at 1000µs + 1 host fetch at 100µs
    assert!((stats.modeled_miss_us - 3100.0).abs() < 1e-9);
}

// ---------------------------------------------------------------------------
// ExpertMemory parity suite: the refactored replay loop (one loop over a
// `Box<dyn ExpertMemory>`) must reproduce the PRE-refactor engine's
// numbers exactly.  The reference implementations below are verbatim
// ports of the seed `SimEngine::run_prompt` flat branch and its
// `run_prompt_tiered` twin, rebuilt from the same public primitives.
// ---------------------------------------------------------------------------

fn random_trace(rng: &mut Rng, n_tokens: usize, n_layers: u16, pool: u8) -> PromptTrace {
    let mut experts = Vec::new();
    for _ in 0..n_tokens * n_layers as usize {
        let a = rng.below(pool as usize) as u8;
        let b = (a + 1 + rng.below(pool as usize - 2) as u8) % pool;
        experts.push(a);
        experts.push(b);
    }
    PromptTrace {
        prompt_id: 0,
        n_layers,
        top_k: 2,
        d_emb: 0,
        tokens: vec![0; n_tokens],
        embeddings: vec![],
        experts,
    }
}

/// Pre-refactor flat replay: one `CachePolicy` + the flat PCIe cost,
/// warm-up tokens unmeasured (port of the seed `run_prompt`).
fn reference_flat_replay(
    trace: &PromptTrace,
    predictor: &mut dyn ExpertPredictor,
    capacity: usize,
    sim: &SimConfig,
    n_experts: usize,
) -> CacheStats {
    let mut cache = LruCache::new(capacity);
    let cache_cfg = CacheConfig::default().with_capacity(capacity);
    let mut stats = CacheStats::default();
    let n_layers = trace.n_layers as usize;
    let warm = sim.warmup_tokens.min(trace.n_tokens());
    predictor.begin_prompt(trace);
    for t in 0..trace.n_tokens() {
        let ctx = DecodeContext { trace, t };
        for l in 0..n_layers {
            let truth = trace.expert_set(t, l);
            if t >= warm {
                let predicted = predictor.predict(&ctx, l);
                let mut landed = 0usize;
                for e in predicted.iter() {
                    stats.prefetches += 1;
                    let k = policy::key(l, e, n_experts);
                    if cache.contains(k) {
                        cache.touch(k);
                        continue;
                    }
                    if landed >= sim.prefetch_budget {
                        stats.wasted_prefetches += 1;
                        continue;
                    }
                    landed += 1;
                    cache.insert(k);
                }
                for e in truth.iter() {
                    stats.prediction_total += 1;
                    if predicted.contains(e) {
                        stats.prediction_hits += 1;
                    }
                }
            }
            for e in truth.iter() {
                let k = policy::key(l, e, n_experts);
                if cache.touch(k) {
                    if t >= warm {
                        stats.hits += 1;
                    }
                } else {
                    if t >= warm {
                        stats.misses += 1;
                        stats.transfer_us += cache_cfg.pcie_us_per_expert;
                    }
                    cache.insert(k);
                }
            }
            predictor.observe(&ctx, l, truth);
        }
    }
    predictor.end_prompt(trace);
    stats
}

/// Pre-refactor tiered replay: `TieredCache` + `TierCostModel` +
/// `TierStats` driven directly (port of the seed `run_prompt_tiered`).
fn reference_tiered_replay(
    trace: &PromptTrace,
    predictor: &mut dyn ExpertPredictor,
    cfg: &TierConfig,
    overlap_budget_us: f64,
    sim: &SimConfig,
    n_experts: usize,
) -> (CacheStats, TierStats, f64) {
    let mut cache = TieredCache::build(&cfg.policy, &cfg.tiers).unwrap();
    let mut cost = TierCostModel::new(cfg.tiers.clone(), overlap_budget_us);
    let mut tstats = TierStats::new(cfg.tiers.len());
    let mut stats = CacheStats::default();
    let n_layers = trace.n_layers as usize;
    let warm = sim.warmup_tokens.min(trace.n_tokens());
    let deepest = cache.deepest();
    predictor.begin_prompt(trace);
    for t in 0..trace.n_tokens() {
        let ctx = DecodeContext { trace, t };
        for l in 0..n_layers {
            let truth = trace.expert_set(t, l);
            if t >= warm {
                let predicted = predictor.predict(&ctx, l);
                let mut landed = 0usize;
                for e in predicted.iter() {
                    stats.prefetches += 1;
                    let k = policy::key(l, e, n_experts);
                    if cache.locate(k) == Some(0) {
                        cache.touch(k);
                        continue;
                    }
                    if landed >= sim.prefetch_budget {
                        stats.wasted_prefetches += 1;
                        continue;
                    }
                    landed += 1;
                    let promo = cache.promote(k);
                    cost.on_prefetch(promo.found.unwrap_or(deepest));
                    tstats.prefetch_promotions += 1;
                    cost.charge_demotions(&mut tstats, &promo);
                }
                for e in truth.iter() {
                    stats.prediction_total += 1;
                    if predicted.contains(e) {
                        stats.prediction_hits += 1;
                    }
                }
            }
            for e in truth.iter() {
                let k = policy::key(l, e, n_experts);
                if cache.locate(k) == Some(0) {
                    cache.touch(k);
                    if t >= warm {
                        stats.hits += 1;
                        tstats.record_served(0);
                        cost.on_hit();
                    }
                } else {
                    let promo = cache.promote(k);
                    if t >= warm {
                        let depth = promo.found.unwrap_or(deepest);
                        stats.misses += 1;
                        stats.transfer_us += cost.fetch_us(depth);
                        match promo.found {
                            Some(d) => tstats.record_served(d),
                            None => tstats.cold += 1,
                        }
                        cost.on_demand_fetch(depth);
                        tstats.promotions += 1;
                        cost.charge_demotions(&mut tstats, &promo);
                    }
                }
            }
            cost.end_layer();
            predictor.observe(&ctx, l, truth);
        }
    }
    predictor.end_prompt(trace);
    let critical = cost.critical_path_us();
    (stats, tstats, critical)
}

fn assert_cache_stats_identical(label: &str, a: &CacheStats, b: &CacheStats) {
    assert_eq!(a.hits, b.hits, "{label}: hits");
    assert_eq!(a.misses, b.misses, "{label}: misses");
    assert_eq!(a.prefetches, b.prefetches, "{label}: prefetches");
    assert_eq!(
        a.wasted_prefetches, b.wasted_prefetches,
        "{label}: wasted_prefetches"
    );
    assert_eq!(a.prediction_hits, b.prediction_hits, "{label}: pred hits");
    assert_eq!(a.prediction_total, b.prediction_total, "{label}: pred total");
    assert_eq!(
        a.transfer_us.to_bits(),
        b.transfer_us.to_bits(),
        "{label}: transfer_us ({} vs {})",
        a.transfer_us,
        b.transfer_us
    );
}

/// Identical traces replayed through `FlatMemory` (via the unified
/// engine) and the pre-refactor flat path produce byte-identical
/// hit/miss/cost numbers, with and without prefetch.
#[test]
fn flat_memory_parity_with_pre_refactor_path() {
    let mut rng = Rng::new(301);
    for case in 0..40 {
        let n_tokens = rng.range(4, 48);
        let tr = random_trace(&mut rng, n_tokens, 3, 16);
        let cap = rng.range(1, 24);
        let sim = SimConfig {
            prefetch_budget: rng.range(1, 6),
            ..Default::default()
        };
        for oracle in [false, true] {
            let reference = if oracle {
                reference_flat_replay(&tr, &mut OraclePredictor::new(), cap, &sim, 16)
            } else {
                reference_flat_replay(&tr, &mut NoPrefetch, cap, &sim, 16)
            };
            let mut engine: SimEngine = SimEngine::flat(
                Box::new(LruCache::new(cap)),
                sim.clone(),
                CacheConfig::default().with_capacity(cap),
                16,
            );
            let mut got = CacheStats::default();
            if oracle {
                engine.run_prompt(&tr, &mut OraclePredictor::new(), &mut got);
            } else {
                engine.run_prompt(&tr, &mut NoPrefetch, &mut got);
            }
            assert_cache_stats_identical(
                &format!("flat case {case} oracle={oracle}"),
                &reference,
                &got,
            );
        }
    }
}

fn parity_tier_config(rng: &mut Rng) -> TierConfig {
    TierConfig {
        tiers: vec![
            TierSpec::new("gpu", rng.range(1, 6), 2.0, 0.0),
            TierSpec::new("host", rng.range(2, 12), 1400.0, 1400.0),
            TierSpec::new("ssd", rng.range(12, 64), 22_000.0, 0.0),
        ],
        policy: "lru".into(),
    }
}

/// Same parity guarantee for the tiered path, including the per-tier
/// serve counters and the modeled critical path.
#[test]
fn tiered_memory_parity_with_pre_refactor_path() {
    let mut rng = Rng::new(302);
    for case in 0..40 {
        let n_tokens = rng.range(4, 48);
        let tr = random_trace(&mut rng, n_tokens, 3, 16);
        let cfg = parity_tier_config(&mut rng);
        let sim = SimConfig {
            prefetch_budget: rng.range(1, 6),
            ..Default::default()
        };
        for oracle in [false, true] {
            let (ref_stats, ref_tiers, ref_critical) = if oracle {
                reference_tiered_replay(&tr, &mut OraclePredictor::new(), &cfg, 1_000.0, &sim, 16)
            } else {
                reference_tiered_replay(&tr, &mut NoPrefetch, &cfg, 1_000.0, &sim, 16)
            };
            let mut engine: SimEngine = SimEngine::tiered(&cfg, sim.clone(), 16, 1_000.0).unwrap();
            let mut got = CacheStats::default();
            if oracle {
                engine.run_prompt(&tr, &mut OraclePredictor::new(), &mut got);
            } else {
                engine.run_prompt(&tr, &mut NoPrefetch, &mut got);
            }
            let label = format!("tiered case {case} oracle={oracle}");
            assert_cache_stats_identical(&label, &ref_stats, &got);
            let m = engine.memory.stats();
            let got_tiers = m.tiers.as_ref().unwrap();
            assert_eq!(ref_tiers.served, got_tiers.served, "{label}: served");
            assert_eq!(ref_tiers.cold, got_tiers.cold, "{label}: cold");
            assert_eq!(ref_tiers.promotions, got_tiers.promotions, "{label}: promotions");
            assert_eq!(
                ref_tiers.prefetch_promotions, got_tiers.prefetch_promotions,
                "{label}: prefetch_promotions"
            );
            assert_eq!(ref_tiers.demotions, got_tiers.demotions, "{label}: demotions");
            assert_eq!(ref_tiers.dropped, got_tiers.dropped, "{label}: dropped");
            assert_eq!(
                ref_critical.to_bits(),
                m.critical_path_us().to_bits(),
                "{label}: critical path {} vs {}",
                ref_critical,
                m.critical_path_us()
            );
        }
    }
}

// ---------------------------------------------------------------------------
// ExpertMemory trait-level invariant suite, run against every backend.
// A third backend gets added to `memory_backends()` and inherits all of
// these checks for free.
// ---------------------------------------------------------------------------

fn memory_backends() -> Vec<(&'static str, Box<dyn Fn() -> Box<dyn ExpertMemory>>)> {
    vec![
        (
            "flat",
            Box::new(|| -> Box<dyn ExpertMemory> {
                Box::new(FlatMemory::new(
                    Box::new(LruCache::new(8)),
                    CacheConfig {
                        capacity_experts: 8,
                        pcie_us_per_expert: 100.0,
                        hit_us: 1.0,
                        ..Default::default()
                    },
                    64,
                    12,
                    1_000.0,
                ))
            }),
        ),
        (
            "tiered",
            Box::new(|| -> Box<dyn ExpertMemory> {
                Box::new(
                    TieredMemory::new(
                        &TierConfig {
                            tiers: vec![
                                TierSpec::new("gpu", 8, 1.0, 0.0),
                                TierSpec::new("host", 16, 100.0, 100.0),
                                TierSpec::new("ssd", 64, 1000.0, 0.0),
                            ],
                            policy: "lru".into(),
                        },
                        64,
                        12,
                        1_000.0,
                    )
                    .unwrap(),
                )
            }),
        ),
    ]
}

#[test]
fn expert_memory_trait_invariants() {
    for (label, mk) in memory_backends() {
        // fresh backend: empty, uncharged
        let mut m = mk();
        assert_eq!(m.resident_count(), 0, "{label}: fresh not empty");
        assert_eq!(m.cost_marks(), (0.0, 0.0), "{label}: fresh cost");
        assert_eq!(m.stats().resident, 0, "{label}: stats/resident disagree");

        // unmeasured (warm-up) lookups move residency but charge nothing
        let r = m.lookup(0, 1, false);
        assert!(!r.hit, "{label}: cold lookup hit");
        assert!(r.fetch_us > 0.0, "{label}: cold miss has no fetch cost");
        assert_eq!(m.cost_marks(), (0.0, 0.0), "{label}: warm-up charged");
        if let Some(ts) = m.tier_stats() {
            assert_eq!(ts.lookups(), 0, "{label}: warm-up counted");
            assert_eq!(ts.promotions, 0, "{label}: warm-up promotion counted");
        }
        assert_eq!(m.resident_count(), 1, "{label}: warm-up didn't admit");
        assert!(m.lookup(0, 1, true).hit, "{label}: admitted key missed");

        // a measured miss charges demand cost; a hit costs (almost) nothing
        let miss = m.lookup(0, 2, true);
        assert!(!miss.hit);
        let (demand, _) = m.cost_marks();
        assert!(demand >= miss.fetch_us, "{label}: miss under-charged");
        assert_eq!(m.lookup(0, 2, true).fetch_us, 0.0, "{label}: hit charged fetch");

        // prefetch: everything is issued, at most the budget lands, and
        // exactly the landed experts become GPU hits
        let mut m = mk();
        m.set_prefetch_budget(2);
        let pf = m.prefetch(3, ExpertSet::from_ids([1u8, 2, 3, 4, 5]));
        assert_eq!(pf.issued, 5, "{label}: issued");
        assert_eq!(pf.landed, 2, "{label}: landed over budget");
        assert_eq!(pf.too_late, 3, "{label}: too_late");
        assert_eq!(m.resident_count(), 2, "{label}: residency after prefetch");
        assert!(m.lookup(3, 1, true).hit, "{label}: landed prefetch missed");
        assert!(m.lookup(3, 2, true).hit, "{label}: landed prefetch missed");

        // batch share divides the base budget and restores exactly
        let mut m = mk();
        m.set_prefetch_budget(12);
        m.set_batch_share(5);
        assert_eq!(m.effective_prefetch_budget(), 2, "{label}: share");
        m.set_batch_share(1);
        assert_eq!(m.effective_prefetch_budget(), 12, "{label}: restore");
        m.set_batch_share(100);
        assert_eq!(m.effective_prefetch_budget(), 1, "{label}: clamp");

        // clear drops residency (cost accumulators are cumulative)
        let mut m = mk();
        m.lookup(0, 9, true);
        m.prefetch(1, ExpertSet::from_ids([4u8, 5]));
        m.clear();
        assert_eq!(m.resident_count(), 0, "{label}: clear left residents");
        let s = m.stats();
        assert_eq!(
            s.resident_per_depth.iter().sum::<usize>(),
            0,
            "{label}: clear left deep residents"
        );

        // stats snapshot coheres with the trait accessors
        let mut m = mk();
        m.lookup(0, 7, true);
        m.end_layer();
        let s = m.stats();
        assert_eq!(s.resident, m.resident_count(), "{label}: stats.resident");
        assert_eq!(
            s.resident_per_depth[0],
            m.resident_count(),
            "{label}: depth-0 residents"
        );
        let (demand, stall) = m.cost_marks();
        assert_eq!(s.demand_us.to_bits(), demand.to_bits(), "{label}: demand");
        assert_eq!(s.stall_us.to_bits(), stall.to_bits(), "{label}: stall");
        assert_eq!(
            s.critical_path_us().to_bits(),
            (demand + stall).to_bits(),
            "{label}: critical path"
        );
        assert_eq!(s.tiers.is_some(), m.tier_stats().is_some(), "{label}: tiers");
    }
}
