//! Cache-policy contract invariants (the `CachePolicy` trait docs) run
//! against EVERY implementation — LRU, LFU, and the offline Belady
//! policy — plus hierarchy invariants for `TieredCache` and the
//! engine's batch-share restore-after-error guarantee.

use moe_beyond::cache::{BeladyCache, CachePolicy, LfuCache, LruCache};
use moe_beyond::config::{CacheConfig, SimConfig, TierConfig};
use moe_beyond::coordinator::{ExpertCacheManager, GenStats};
use moe_beyond::tier::{TierSpec, TieredCache};
use moe_beyond::util::{ExpertSet, Rng};

/// Drive a policy with a random op mix, checking after every op:
/// * `len() <= capacity()`,
/// * `insert` of a resident key only refreshes (no eviction, no growth),
/// * evictions happen only on insert into a full cache, one per insert,
/// * `resident()` agrees with `len()` and `contains()`.
fn check_contract(name: &str, mk: &dyn Fn(usize) -> Box<dyn CachePolicy>, seed: u64) {
    let mut rng = Rng::new(seed);
    for _case in 0..60 {
        let cap = rng.range(1, 10);
        let mut c = mk(cap);
        assert_eq!(c.capacity(), cap, "{name}: capacity mismatch");
        for _ in 0..rng.range(1, 150) {
            let k = rng.below(25) as u32;
            match rng.below(3) {
                0 => {
                    let was_resident = c.contains(k);
                    let len_before = c.len();
                    let evicted = c.insert(k);
                    if was_resident {
                        assert_eq!(evicted, None, "{name}: refresh must not evict");
                        assert_eq!(c.len(), len_before, "{name}: refresh must not grow");
                    } else if len_before == cap {
                        let v = evicted.unwrap_or_else(|| {
                            panic!("{name}: full insert must evict exactly one")
                        });
                        assert_ne!(v, k, "{name}: evicted the key being inserted");
                        assert!(!c.contains(v), "{name}: victim still resident");
                        assert_eq!(c.len(), cap);
                    } else {
                        assert_eq!(evicted, None, "{name}: evicted below capacity");
                        assert_eq!(c.len(), len_before + 1);
                    }
                    assert!(c.contains(k), "{name}: inserted key not resident");
                }
                1 => {
                    let hit = c.touch(k);
                    assert_eq!(hit, c.contains(k), "{name}: touch() vs contains()");
                }
                _ => {
                    let was = c.contains(k);
                    assert_eq!(c.evict(k), was, "{name}: evict() return value");
                    assert!(!c.contains(k), "{name}: evicted key still resident");
                }
            }
            assert!(c.len() <= c.capacity(), "{name}: len exceeds capacity");
            let resident = c.resident();
            assert_eq!(resident.len(), c.len(), "{name}: resident()/len() disagree");
            for &r in &resident {
                assert!(c.contains(r), "{name}: resident key not contained");
            }
        }
    }
}

#[test]
fn lru_satisfies_contract() {
    check_contract("lru", &|cap| Box::new(LruCache::new(cap)), 101);
}

#[test]
fn lfu_satisfies_contract() {
    check_contract("lfu", &|cap| Box::new(LfuCache::new(cap)), 102);
}

#[test]
fn belady_satisfies_contract() {
    // unprimed: every next-use is "never", eviction order is arbitrary
    // but the contract must still hold
    check_contract("belady", &|cap| Box::new(BeladyCache::new(cap)), 103);
    // primed with a future reference string
    check_contract(
        "belady-primed",
        &|cap| {
            let mut c = BeladyCache::new(cap);
            let mut rng = Rng::new(cap as u64);
            let reference: Vec<u32> = (0..200).map(|_| rng.below(25) as u32).collect();
            c.prime(&reference);
            Box::new(c)
        },
        104,
    );
}

/// TieredCache promotion/demotion invariants across random promote
/// streams over a deep hierarchy.
#[test]
fn tiered_cache_promotion_demotion_invariants() {
    let mut rng = Rng::new(105);
    for _case in 0..60 {
        let caps = [rng.range(1, 4), rng.range(1, 6), rng.range(1, 8)];
        let mut c = TieredCache::new(vec![
            Box::new(LruCache::new(caps[0])),
            Box::new(LfuCache::new(caps[1])),
            Box::new(LruCache::new(caps[2])),
        ]);
        let mut total_before = 0usize;
        for _ in 0..rng.range(1, 150) {
            let k = rng.below(30) as u32;
            let was_cold = c.locate(k).is_none();
            let p = c.promote(k);
            assert_eq!(p.found.is_none(), was_cold);
            assert_eq!(c.locate(k), Some(0), "promoted key must be at the top");
            // at most one demotion per tier, strictly downward
            assert!(p.demoted.len() <= 3);
            for d in &p.demoted {
                if let Some(to) = d.to {
                    assert_eq!(to, d.from + 1, "demotion must go one tier down");
                    assert_eq!(c.locate(d.key), Some(to));
                } else {
                    assert!(c.locate(d.key).is_none(), "dropped key still resident");
                }
            }
            // conservation: a promotion adds at most one resident copy
            let total = c.resident_total();
            assert!(total <= total_before + 1);
            total_before = total;
            for (depth, &cap) in caps.iter().enumerate() {
                assert!(c.len_at(depth) <= cap);
            }
        }
    }
}

/// The engine restores the full prefetch window after batch processing
/// even on error paths (`process_batch` restructures around a single
/// restore point); the manager-level restore must therefore be exact
/// and idempotent from any prior share.
#[test]
fn batch_share_restore_after_error_semantics() {
    let mut m = ExpertCacheManager::new(
        Box::new(LruCache::new(32)),
        CacheConfig::default(),
        64,
        1_000.0,
    )
    .with_prefetch_budget(12);

    // simulate the error path: share set for a batch, "error", restore
    for batch in [2usize, 3, 7, 64] {
        m.set_batch_share(batch);
        assert_eq!(m.effective_prefetch_budget(), (12 / batch).max(1));
        m.set_batch_share(1);
        assert_eq!(
            m.effective_prefetch_budget(),
            12,
            "window not restored after batch={batch}"
        );
    }

    // the default budget is the shared SimConfig knob, not a magic 12
    let fresh = ExpertCacheManager::new(
        Box::new(LruCache::new(32)),
        CacheConfig::default(),
        64,
        1_000.0,
    );
    assert_eq!(
        fresh.effective_prefetch_budget(),
        SimConfig::default().prefetch_budget
    );
}

/// End-to-end tiered manager: a demand miss on a GPU-full cache demotes
/// into the host tier, and a later access to the demoted expert is
/// served from host (cheap) rather than flash (expensive).
#[test]
fn tiered_manager_promotion_path() {
    let cfg = TierConfig {
        tiers: vec![
            TierSpec::new("gpu", 2, 1.0, 0.0),
            TierSpec::new("host", 8, 100.0, 100.0),
            TierSpec::new("ssd", 64, 1000.0, 0.0),
        ],
        policy: "lru".into(),
    };
    let mut m = ExpertCacheManager::new_tiered(&cfg, 64, 10_000.0).unwrap();
    let mut stats = GenStats::default();
    m.observe_actual(0, ExpertSet::from_ids([1u8, 2, 3]), &mut stats);
    // expert 1 was demoted to host; touching it again promotes it back
    m.observe_actual(0, ExpertSet::from_ids([1u8]), &mut stats);
    let ts = m.tier_stats().unwrap();
    assert_eq!(ts.cold, 3);
    assert_eq!(ts.served[1], 1);
    assert!(ts.demotions >= 1);
    m.finish(&mut stats);
    // 3 cold reads at 1000µs + 1 host fetch at 100µs
    assert!((stats.modeled_miss_us - 3100.0).abs() < 1e-9);
}
