//! Cluster-backend parity and determinism suites.
//!
//! The multi-node cluster simulator (`moe_beyond::cluster`) is one more
//! `ExpertMemory` backend, so it is held to the same structural
//! guarantees as every other fast path in this repo:
//!
//! * a K=1 cluster over a zero-cost loopback link is BYTE-identical to
//!   the single-node backend it wraps — for flat nodes and for full
//!   tiered hierarchies, over random-trace replays with and without an
//!   oracle prefetcher,
//! * the native `lookup_set` matches the trait-default scalar
//!   delegation (`memory::ScalarPath`) on a live K=3 cluster,
//! * a seeded K=3 run with an injected node failure and a straggler
//!   link is byte-identical across two full replays.

use moe_beyond::cache::CacheStats;
use moe_beyond::cluster::{self, ClusterConfig, FaultPlan, PlacementKind};
use moe_beyond::config::{CacheConfig, SimConfig, TierConfig};
use moe_beyond::memory::{self, ExpertMemory, ScalarPath};
use moe_beyond::predictor::{NoPrefetch, OraclePredictor};
use moe_beyond::sim::SimEngine;
use moe_beyond::tier::{LinkSpec, TierSpec};
use moe_beyond::trace::PromptTrace;
use moe_beyond::util::Rng;

const N_EXPERTS: usize = 16;

fn random_trace(rng: &mut Rng, n_tokens: usize, n_layers: u16, pool: u8) -> PromptTrace {
    let mut experts = Vec::new();
    for _ in 0..n_tokens * n_layers as usize {
        let a = rng.below(pool as usize) as u8;
        let b = (a + 1 + rng.below(pool as usize - 2) as u8) % pool;
        experts.push(a);
        experts.push(b);
    }
    PromptTrace {
        prompt_id: 0,
        n_layers,
        top_k: 2,
        d_emb: 0,
        tokens: vec![0; n_tokens],
        embeddings: vec![],
        experts,
    }
}

fn assert_stats_identical(label: &str, a: &CacheStats, b: &CacheStats) {
    assert_eq!(a.hits, b.hits, "{label}: hits");
    assert_eq!(a.misses, b.misses, "{label}: misses");
    assert_eq!(a.prefetches, b.prefetches, "{label}: prefetches");
    assert_eq!(a.wasted_prefetches, b.wasted_prefetches, "{label}: wasted");
    assert_eq!(a.prediction_hits, b.prediction_hits, "{label}: pred hits");
    assert_eq!(a.prediction_total, b.prediction_total, "{label}: pred total");
    assert_eq!(
        a.transfer_us.to_bits(),
        b.transfer_us.to_bits(),
        "{label}: transfer_us ({} vs {})",
        a.transfer_us,
        b.transfer_us
    );
}

fn run_engine(
    mut memory: Box<dyn ExpertMemory>,
    traces: &[PromptTrace],
    sim: &SimConfig,
    oracle: bool,
) -> (CacheStats, (f64, f64), usize) {
    let mut stats = CacheStats::default();
    memory.set_prefetch_budget(sim.prefetch_budget);
    let mut engine = SimEngine::new(memory, sim.clone(), N_EXPERTS);
    for tr in traces {
        if oracle {
            engine.run_prompt(tr, &mut OraclePredictor::new(), &mut stats);
        } else {
            engine.run_prompt(tr, &mut NoPrefetch, &mut stats);
        }
    }
    let marks = engine.memory.cost_marks();
    let resident = engine.memory.resident_count();
    (stats, marks, resident)
}

/// K=1 loopback cluster around flat LRU nodes vs the flat backend
/// itself: full random-trace replays must agree in every counter, every
/// modeled cost bit, and the final residency.
#[test]
fn k1_loopback_cluster_matches_flat_replay_bit_for_bit() {
    let mut rng = Rng::new(601);
    for case in 0..20 {
        let n_prompts = rng.range(1, 4);
        let traces: Vec<PromptTrace> = (0..n_prompts)
            .map(|_| {
                let n_tokens = rng.range(4, 40);
                random_trace(&mut rng, n_tokens, 3, 16)
            })
            .collect();
        let cap = rng.range(1, 24);
        let sim = SimConfig {
            prefetch_budget: rng.range(1, 6),
            warmup_tokens: rng.below(10),
            ..Default::default()
        };
        let cache = CacheConfig::default().with_capacity(cap);
        let cfg = ClusterConfig::default(); // 1 node, loopback link
        for oracle in [false, true] {
            let clustered =
                cluster::build::<1>(&cfg, "lru", &cache, None, &sim, N_EXPERTS, 1_000.0).unwrap();
            let single =
                memory::build::<1>("lru", &cache, None, &sim, N_EXPERTS, 1_000.0).unwrap();
            let (cs, cm, cr) = run_engine(clustered, &traces, &sim, oracle);
            let (ss, sm, sr) = run_engine(single, &traces, &sim, oracle);
            let label = format!("flat case {case} oracle={oracle}");
            assert_stats_identical(&label, &ss, &cs);
            assert_eq!(cm.0.to_bits(), sm.0.to_bits(), "{label}: demand marks");
            assert_eq!(cm.1.to_bits(), sm.1.to_bits(), "{label}: stall marks");
            assert_eq!(cr, sr, "{label}: residency");
        }
    }
}

/// Same guarantee with full tiered hierarchies inside each node: the
/// K=1 loopback cluster replays byte-identically to the single-node
/// tiered backend, per-tier counters included.
#[test]
fn k1_loopback_cluster_matches_tiered_replay_bit_for_bit() {
    let mut rng = Rng::new(602);
    for case in 0..12 {
        let traces: Vec<PromptTrace> = (0..rng.range(1, 4))
            .map(|_| {
                let n_tokens = rng.range(4, 40);
                random_trace(&mut rng, n_tokens, 3, 16)
            })
            .collect();
        let tier = TierConfig {
            tiers: vec![
                TierSpec::new("gpu", rng.range(1, 6), 2.0, 0.0),
                TierSpec::new("host", rng.range(2, 12), 1400.0, 1400.0),
                TierSpec::new("ssd", rng.range(12, 64), 22_000.0, 0.0),
            ],
            policy: "lru".into(),
        };
        let sim = SimConfig {
            prefetch_budget: rng.range(1, 6),
            warmup_tokens: rng.below(10),
            ..Default::default()
        };
        let cache = CacheConfig::default();
        let cfg = ClusterConfig::default();
        for oracle in [false, true] {
            let clustered = cluster::build::<1>(
                &cfg, "lru", &cache, Some(&tier), &sim, N_EXPERTS, 1_000.0,
            )
            .unwrap();
            let single =
                memory::build::<1>("lru", &cache, Some(&tier), &sim, N_EXPERTS, 1_000.0).unwrap();
            let mut ce = SimEngine::new(clustered, sim.clone(), N_EXPERTS);
            let mut se = SimEngine::new(single, sim.clone(), N_EXPERTS);
            let (mut cs, mut ss) = (CacheStats::default(), CacheStats::default());
            for tr in &traces {
                if oracle {
                    ce.run_prompt(tr, &mut OraclePredictor::new(), &mut cs);
                    se.run_prompt(tr, &mut OraclePredictor::new(), &mut ss);
                } else {
                    ce.run_prompt(tr, &mut NoPrefetch, &mut cs);
                    se.run_prompt(tr, &mut NoPrefetch, &mut ss);
                }
            }
            let label = format!("tiered case {case} oracle={oracle}");
            assert_stats_identical(&label, &ss, &cs);
            let (cm, sm) = (ce.memory.stats(), se.memory.stats());
            assert_eq!(
                cm.critical_path_us().to_bits(),
                sm.critical_path_us().to_bits(),
                "{label}: critical path"
            );
            assert_eq!(cm.resident_per_depth, sm.resident_per_depth, "{label}: depth");
            let (ct, st) = (cm.tiers.as_ref().unwrap(), sm.tiers.as_ref().unwrap());
            assert_eq!(ct.served, st.served, "{label}: served");
            assert_eq!(ct.cold, st.cold, "{label}: cold");
            assert_eq!(ct.promotions, st.promotions, "{label}: promotions");
            assert_eq!(ct.demotions, st.demotions, "{label}: demotions");
            assert_eq!(ct.dropped, st.dropped, "{label}: dropped");
            // loopback link, one node: the network tier never engaged
            let net = cm.net.as_ref().unwrap();
            assert_eq!(net.remote_lookups, 0, "{label}: remote lookups");
            assert_eq!(net.total_us(), 0.0, "{label}: wire time");
        }
    }
}

/// Native cluster `lookup_set` vs the trait-default scalar delegation on
/// a live K=3 cluster with a priced LAN link and migration enabled.
#[test]
fn cluster_batched_lookup_matches_scalar_delegation() {
    let mut rng = Rng::new(603);
    let cfg = ClusterConfig::default()
        .with_nodes(3)
        .with_placement(PlacementKind::LayerHash)
        .with_link(LinkSpec::lan())
        .with_promote_after(3);
    for case in 0..15 {
        let traces: Vec<PromptTrace> = (0..rng.range(1, 4))
            .map(|_| {
                let n_tokens = rng.range(4, 40);
                random_trace(&mut rng, n_tokens, 3, 16)
            })
            .collect();
        let cap = rng.range(1, 12);
        let sim = SimConfig {
            prefetch_budget: rng.range(1, 6),
            warmup_tokens: rng.below(10),
            ..Default::default()
        };
        let cache = CacheConfig::default().with_capacity(cap);
        let mk = || cluster::build::<1>(&cfg, "lru", &cache, None, &sim, N_EXPERTS, 1_000.0)
            .unwrap();
        for oracle in [false, true] {
            let (native, nm, nr) = run_engine(mk(), &traces, &sim, oracle);
            let (scalar, sm, sr) =
                run_engine(Box::new(ScalarPath::new(mk())), &traces, &sim, oracle);
            let label = format!("cluster case {case} oracle={oracle}");
            assert_stats_identical(&label, &scalar, &native);
            assert_eq!(nm.0.to_bits(), sm.0.to_bits(), "{label}: demand marks");
            assert_eq!(nm.1.to_bits(), sm.1.to_bits(), "{label}: stall marks");
            assert_eq!(nr, sr, "{label}: residency");
        }
    }
}

/// A seeded K=3 replay with an injected node failure, a straggler link,
/// and hot-expert migration is byte-identical across two full runs —
/// the fault clock ticks on measured lookups, not wall time.
#[test]
fn seeded_faulty_cluster_replay_is_byte_identical_across_runs() {
    let cfg = ClusterConfig::default()
        .with_nodes(3)
        .with_placement(PlacementKind::RoundRobin)
        .with_link(LinkSpec::new(50.0, 1.0, 5.0))
        .with_promote_after(2)
        .with_faults(FaultPlan::none().with_failure(2, 40).with_straggler(1, 2.5));
    let run = || {
        let mut rng = Rng::new(604);
        let traces: Vec<PromptTrace> = (0..4)
            .map(|_| random_trace(&mut rng, 32, 3, 16))
            .collect();
        let sim = SimConfig::default();
        let cache = CacheConfig::default().with_capacity(6);
        let memory =
            cluster::build::<1>(&cfg, "lru", &cache, None, &sim, N_EXPERTS, 1_000.0).unwrap();
        let (stats, marks, resident) = run_engine(memory, &traces, &sim, true);
        (
            stats.hits,
            stats.misses,
            stats.prefetches,
            stats.transfer_us.to_bits(),
            marks.0.to_bits(),
            marks.1.to_bits(),
            resident,
        )
    };
    let a = run();
    assert_eq!(a, run(), "two identical faulty-cluster runs diverged");
    // the failure actually engaged: enough measured lookups to pass 40
    assert!(a.0 + a.1 > 40, "scenario too small to exercise the failure");
}

/// The replication machinery is inert at R=1 with no faults: a K=3
/// cluster configured through the new knobs (`with_replicas(1)` plus an
/// explicit retry backoff, which is unreachable while the deadline is
/// off) replays byte-identically to the plain pre-replication config —
/// the old single-owner path survives unchanged.
#[test]
fn r1_cluster_with_replication_knobs_matches_single_owner_bit_for_bit() {
    let mut rng = Rng::new(605);
    for placement in [PlacementKind::RoundRobin, PlacementKind::LayerHash] {
        for case in 0..8 {
            let traces: Vec<PromptTrace> = (0..rng.range(1, 4))
                .map(|_| {
                    let n_tokens = rng.range(4, 40);
                    random_trace(&mut rng, n_tokens, 3, 16)
                })
                .collect();
            let cap = rng.range(1, 12);
            let sim = SimConfig {
                prefetch_budget: rng.range(1, 6),
                warmup_tokens: rng.below(10),
                ..Default::default()
            };
            let cache = CacheConfig::default().with_capacity(cap);
            let old = ClusterConfig::default()
                .with_nodes(3)
                .with_placement(placement)
                .with_link(LinkSpec::new(50.0, 1.0, 5.0))
                .with_promote_after(2);
            let knobs = old.clone().with_replicas(1).with_retry_backoff_us(777.0);
            for oracle in [false, true] {
                let mk = |cfg: &ClusterConfig| {
                    cluster::build::<1>(cfg, "lru", &cache, None, &sim, N_EXPERTS, 1_000.0)
                        .unwrap()
                };
                let (s1, m1, r1) = run_engine(mk(&old), &traces, &sim, oracle);
                let (s2, m2, r2) = run_engine(mk(&knobs), &traces, &sim, oracle);
                let label = format!("{placement:?} case {case} oracle={oracle}");
                assert_stats_identical(&label, &s1, &s2);
                assert_eq!(m1.0.to_bits(), m2.0.to_bits(), "{label}: demand marks");
                assert_eq!(m1.1.to_bits(), m2.1.to_bits(), "{label}: stall marks");
                assert_eq!(r1, r2, "{label}: residency");
            }
        }
    }
}

/// Full replication puts a rank of every expert on the front node, and
/// the cheapest-reachable-replica rule always prefers hops 0 — so a
/// healthy K=3, R=3 cluster serves every lookup locally and replays
/// byte-identically to the single-node backend, with the wire never
/// engaging.
#[test]
fn fully_replicated_cluster_serves_locally_and_matches_single_node() {
    let mut rng = Rng::new(607);
    for case in 0..8 {
        let traces: Vec<PromptTrace> = (0..rng.range(1, 4))
            .map(|_| {
                let n_tokens = rng.range(4, 40);
                random_trace(&mut rng, n_tokens, 3, 16)
            })
            .collect();
        let cap = rng.range(1, 12);
        let sim = SimConfig {
            prefetch_budget: rng.range(1, 6),
            warmup_tokens: rng.below(10),
            ..Default::default()
        };
        let cache = CacheConfig::default().with_capacity(cap);
        let cfg = ClusterConfig::default()
            .with_nodes(3)
            .with_link(LinkSpec::new(50.0, 1.0, 5.0))
            .with_replicas(3);
        for oracle in [false, true] {
            let mut clustered =
                cluster::build::<1>(&cfg, "lru", &cache, None, &sim, N_EXPERTS, 1_000.0)
                    .unwrap();
            let mut single =
                memory::build::<1>("lru", &cache, None, &sim, N_EXPERTS, 1_000.0).unwrap();
            clustered.set_prefetch_budget(sim.prefetch_budget);
            single.set_prefetch_budget(sim.prefetch_budget);
            let mut ce = SimEngine::new(clustered, sim.clone(), N_EXPERTS);
            let mut se = SimEngine::new(single, sim.clone(), N_EXPERTS);
            let (mut cs, mut ss) = (CacheStats::default(), CacheStats::default());
            for tr in &traces {
                if oracle {
                    ce.run_prompt(tr, &mut OraclePredictor::new(), &mut cs);
                    se.run_prompt(tr, &mut OraclePredictor::new(), &mut ss);
                } else {
                    ce.run_prompt(tr, &mut NoPrefetch, &mut cs);
                    se.run_prompt(tr, &mut NoPrefetch, &mut ss);
                }
            }
            let label = format!("full-replication case {case} oracle={oracle}");
            assert_stats_identical(&label, &ss, &cs);
            let (cm, sm) = (ce.memory.cost_marks(), se.memory.cost_marks());
            assert_eq!(cm.0.to_bits(), sm.0.to_bits(), "{label}: demand marks");
            assert_eq!(cm.1.to_bits(), sm.1.to_bits(), "{label}: stall marks");
            assert_eq!(
                ce.memory.resident_count(),
                se.memory.resident_count(),
                "{label}: residency"
            );
            let net = ce.memory.stats().net.unwrap();
            assert_eq!(net.remote_lookups, 0, "{label}: remote lookups");
            assert_eq!(net.total_us(), 0.0, "{label}: wire time");
        }
    }
}

/// A seeded chaos run — recovery windows taking both replicas of some
/// experts down at once, a straggler behind a fetch deadline (so the
/// retry/backoff chain engages), a link flap, and a slow-link episode —
/// replays byte-identically across two full runs, serves every lookup
/// without panicking, and actually exercises the degraded and retry
/// paths.
#[test]
fn seeded_chaos_replay_is_byte_identical_and_degrades_without_panic() {
    let cfg = ClusterConfig::default()
        .with_nodes(3)
        .with_placement(PlacementKind::RoundRobin)
        .with_link(LinkSpec::new(50.0, 0.0, 5.0).with_timeout_us(100.0))
        .with_replicas(2)
        .with_retry_backoff_us(25.0)
        .with_faults(
            FaultPlan::none()
                .with_down_window(1, 10, 60)
                .with_link_flap(2, 20, 50)
                .with_straggler(1, 4.0)
                .with_slow_link(2, 80, 120, 3.0),
        );
    let run = || {
        let mut rng = Rng::new(606);
        let traces: Vec<PromptTrace> = (0..4)
            .map(|_| random_trace(&mut rng, 32, 3, 16))
            .collect();
        let sim = SimConfig::default();
        let cache = CacheConfig::default().with_capacity(6);
        let mut memory =
            cluster::build::<1>(&cfg, "lru", &cache, None, &sim, N_EXPERTS, 1_000.0).unwrap();
        memory.set_prefetch_budget(sim.prefetch_budget);
        let mut stats = CacheStats::default();
        let mut engine = SimEngine::new(memory, sim.clone(), N_EXPERTS);
        for tr in &traces {
            engine.run_prompt(tr, &mut OraclePredictor::new(), &mut stats);
        }
        let m = engine.memory.stats();
        let net = m.net.expect("cluster backend reports NetStats");
        let marks = engine.memory.cost_marks();
        (stats, net, marks)
    };
    let (s1, n1, m1) = run();
    let (s2, n2, m2) = run();
    assert_stats_identical("chaos replay", &s1, &s2);
    assert_eq!(n1, n2, "chaos replay: NetStats diverged");
    assert_eq!(m1.0.to_bits(), m2.0.to_bits(), "chaos replay: demand marks");
    assert_eq!(m1.1.to_bits(), m2.1.to_bits(), "chaos replay: stall marks");
    // the chaos actually bit: both replicas down at once forced the
    // degraded deepest-tier path, and the deadline forced retries
    assert!(
        n1.degraded_fetches > 0,
        "overlapping down+flap windows should have forced degraded fetches"
    );
    assert!(n1.retries > 0, "the 100µs deadline should have forced retries");
    assert!(n1.failovers > 0, "down windows should have forced failovers");
    assert!(n1.timeout_us > 0.0 && n1.backoff_us > 0.0);
    // every measured lookup was served: hits + misses covers the corpus
    assert!(s1.hits + s1.misses > 0);
}
