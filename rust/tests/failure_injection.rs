//! Failure-injection tests: corrupted or inconsistent artifact trees must
//! be rejected loudly at load time, never produce silent wrong numbers.

use moe_beyond::config::Artifacts;
use moe_beyond::runtime::WeightBlob;
use moe_beyond::trace::store;

fn real_artifacts() -> Option<std::path::PathBuf> {
    let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    root.join("artifacts.json").exists().then_some(root)
}

fn temp_dir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("moeb_fi_{name}"));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn truncated_trace_file_rejected() {
    let Some(root) = real_artifacts() else { return };
    let src = std::fs::read(root.join("traces/val.bin")).unwrap();
    let dir = temp_dir("trunc");
    let p = dir.join("t.bin");
    std::fs::write(&p, &src[..src.len() / 2]).unwrap();
    assert!(store::read_traces(&p).is_err());
}

#[test]
fn out_of_range_expert_id_rejected() {
    let Some(root) = real_artifacts() else { return };
    let mut src = std::fs::read(root.join("traces/val.bin")).unwrap();
    // corrupt one expert byte past the embeddings of the first prompt:
    // header 24B + pid/ntok 8B; tokens + embeddings follow — flip the LAST
    // byte of the file (inside the final prompt's expert array)
    let n = src.len();
    src[n - 1] = 255;
    let dir = temp_dir("range");
    let p = dir.join("t.bin");
    std::fs::write(&p, &src).unwrap();
    assert!(store::read_traces(&p).is_err());
}

#[test]
fn weights_manifest_total_mismatch_rejected() {
    let Some(root) = real_artifacts() else { return };
    let dir = temp_dir("weights");
    std::fs::copy(
        root.join("predictor_weights.bin"),
        dir.join("w.bin"),
    )
    .unwrap();
    let man = std::fs::read_to_string(root.join("predictor_weights.bin.json")).unwrap();
    // inflate total_f32 so it no longer matches the file
    let bad = man.replacen("\"total_f32\":", "\"total_f32\": 1 +", 1)
        .replace("+", "");
    // simpler: just truncate the bin instead
    let raw = std::fs::read(dir.join("w.bin")).unwrap();
    std::fs::write(dir.join("w.bin"), &raw[..raw.len() - 4]).unwrap();
    std::fs::write(dir.join("w.bin.json"), &bad).unwrap();
    assert!(WeightBlob::load(dir.join("w.bin")).is_err());
}

#[test]
fn fingerprint_mismatch_rejected() {
    let Some(root) = real_artifacts() else { return };
    let dir = temp_dir("fp");
    // copy the manifest tree but lie about the predictor fingerprint
    for f in [
        "artifacts.json",
        "predictor.hlo.txt",
        "predictor_batch.hlo.txt",
        "backbone_prefill.hlo.txt",
        "backbone_prefill_96.hlo.txt",
        "backbone_decode.hlo.txt",
        "head_extract.hlo.txt",
    ] {
        std::fs::copy(root.join(f), dir.join(f)).unwrap();
    }
    let man = std::fs::read_to_string(root.join("predictor_weights.bin.json")).unwrap();
    let bad = man.replace("\"fingerprint\": \"w", "\"fingerprint\": \"DIFFERENT-w");
    std::fs::write(dir.join("predictor_weights.bin.json"), bad).unwrap();
    let arts = Artifacts::discover(&dir).unwrap();
    assert!(arts.check_fingerprint().is_err());
}

#[test]
fn missing_executable_rejected() {
    let Some(root) = real_artifacts() else { return };
    let dir = temp_dir("noexe");
    std::fs::copy(root.join("artifacts.json"), dir.join("artifacts.json")).unwrap();
    // no hlo files copied -> discover must fail
    assert!(Artifacts::discover(&dir).is_err());
}

#[test]
fn garbage_hlo_rejected_at_compile() {
    let dir = temp_dir("badhlo");
    let p = dir.join("bad.hlo.txt");
    std::fs::write(&p, "HloModule not_really { this is not hlo }").unwrap();
    let rt = moe_beyond::runtime::PjrtRuntime::cpu().unwrap();
    assert!(rt.load_hlo_text(&p).is_err());
}
