//! Failure-injection tests, two families:
//!
//! * artifact-tree faults: corrupted or inconsistent artifact trees must
//!   be rejected loudly at load time, never produce silent wrong numbers
//!   (these skip silently when the artifact tree is absent);
//! * cluster faults: scheduled node failures and straggler links in the
//!   multi-node edge-cluster simulator must change results in the
//!   direction physics demands, deterministically — these run
//!   self-contained on synthetic lookups, no artifacts needed.

use moe_beyond::cluster::{self, ClusterConfig, FaultPlan, PlacementKind};
use moe_beyond::config::{Artifacts, CacheConfig, SimConfig};
use moe_beyond::memory::ExpertMemory;
use moe_beyond::runtime::WeightBlob;
use moe_beyond::tier::LinkSpec;
use moe_beyond::trace::store;

fn real_artifacts() -> Option<std::path::PathBuf> {
    let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    root.join("artifacts.json").exists().then_some(root)
}

fn temp_dir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("moeb_fi_{name}"));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn truncated_trace_file_rejected() {
    let Some(root) = real_artifacts() else { return };
    let src = std::fs::read(root.join("traces/val.bin")).unwrap();
    let dir = temp_dir("trunc");
    let p = dir.join("t.bin");
    std::fs::write(&p, &src[..src.len() / 2]).unwrap();
    assert!(store::read_traces(&p).is_err());
}

#[test]
fn out_of_range_expert_id_rejected() {
    let Some(root) = real_artifacts() else { return };
    let mut src = std::fs::read(root.join("traces/val.bin")).unwrap();
    // corrupt one expert byte past the embeddings of the first prompt:
    // header 24B + pid/ntok 8B; tokens + embeddings follow — flip the LAST
    // byte of the file (inside the final prompt's expert array)
    let n = src.len();
    src[n - 1] = 255;
    let dir = temp_dir("range");
    let p = dir.join("t.bin");
    std::fs::write(&p, &src).unwrap();
    assert!(store::read_traces(&p).is_err());
}

#[test]
fn weights_manifest_total_mismatch_rejected() {
    let Some(root) = real_artifacts() else { return };
    let dir = temp_dir("weights");
    std::fs::copy(
        root.join("predictor_weights.bin"),
        dir.join("w.bin"),
    )
    .unwrap();
    let man = std::fs::read_to_string(root.join("predictor_weights.bin.json")).unwrap();
    // inflate total_f32 so it no longer matches the file
    let bad = man.replacen("\"total_f32\":", "\"total_f32\": 1 +", 1)
        .replace("+", "");
    // simpler: just truncate the bin instead
    let raw = std::fs::read(dir.join("w.bin")).unwrap();
    std::fs::write(dir.join("w.bin"), &raw[..raw.len() - 4]).unwrap();
    std::fs::write(dir.join("w.bin.json"), &bad).unwrap();
    assert!(WeightBlob::load(dir.join("w.bin")).is_err());
}

#[test]
fn fingerprint_mismatch_rejected() {
    let Some(root) = real_artifacts() else { return };
    let dir = temp_dir("fp");
    // copy the manifest tree but lie about the predictor fingerprint
    for f in [
        "artifacts.json",
        "predictor.hlo.txt",
        "predictor_batch.hlo.txt",
        "backbone_prefill.hlo.txt",
        "backbone_prefill_96.hlo.txt",
        "backbone_decode.hlo.txt",
        "head_extract.hlo.txt",
    ] {
        std::fs::copy(root.join(f), dir.join(f)).unwrap();
    }
    let man = std::fs::read_to_string(root.join("predictor_weights.bin.json")).unwrap();
    let bad = man.replace("\"fingerprint\": \"w", "\"fingerprint\": \"DIFFERENT-w");
    std::fs::write(dir.join("predictor_weights.bin.json"), bad).unwrap();
    let arts = Artifacts::discover(&dir).unwrap();
    assert!(arts.check_fingerprint().is_err());
}

#[test]
fn missing_executable_rejected() {
    let Some(root) = real_artifacts() else { return };
    let dir = temp_dir("noexe");
    std::fs::copy(root.join("artifacts.json"), dir.join("artifacts.json")).unwrap();
    // no hlo files copied -> discover must fail
    assert!(Artifacts::discover(&dir).is_err());
}

#[test]
fn garbage_hlo_rejected_at_compile() {
    let dir = temp_dir("badhlo");
    let p = dir.join("bad.hlo.txt");
    std::fs::write(&p, "HloModule not_really { this is not hlo }").unwrap();
    let rt = moe_beyond::runtime::PjrtRuntime::cpu().unwrap();
    assert!(rt.load_hlo_text(&p).is_err());
}

// ---- cluster fault injection (self-contained, no artifacts) ----------

fn faulty_cluster(cfg: &ClusterConfig) -> Box<dyn ExpertMemory> {
    cluster::build::<1>(
        cfg,
        "lru",
        &CacheConfig::default().with_capacity(4),
        None,
        &SimConfig::default(),
        64,
        1_000.0,
    )
    .unwrap()
}

/// Drive a fixed synthetic access pattern and return the fault-relevant
/// observables (all bit-exact fields).
fn drive(cfg: &ClusterConfig) -> (u64, u64, u64, u64) {
    let mut c = faulty_cluster(cfg);
    for t in 0..120usize {
        c.lookup(t % 4, ((t * 5) % 64) as u8, true);
        if t % 8 == 7 {
            c.end_layer();
        }
    }
    let net = c.stats().net.expect("cluster backend reports net stats");
    (
        net.remote_lookups,
        net.failovers,
        net.promotions,
        net.total_us().to_bits(),
    )
}

/// A scheduled node failure reroutes every lookup the dead node owned
/// (ring failover), and does so identically on every run.
#[test]
fn node_failure_scenario_is_deterministic_and_reroutes() {
    let healthy = ClusterConfig::default()
        .with_nodes(3)
        .with_link(LinkSpec::lan());
    let faulty = healthy
        .clone()
        .with_faults(FaultPlan::none().with_failure(1, 30));
    let h = drive(&healthy);
    let f = drive(&faulty);
    assert_eq!(h.1, 0, "healthy cluster must not fail over");
    assert!(f.1 > 0, "failure at lookup 30 must trigger failovers");
    // determinism: same plan, same numbers, bit for bit
    assert_eq!(f, drive(&faulty));
    assert_eq!(h, drive(&healthy));
}

/// A straggler link only inflates wire time — routing, failovers, and
/// promotion behavior are untouched.
#[test]
fn straggler_scenario_slows_the_wire_but_not_the_routing() {
    let base = ClusterConfig::default()
        .with_nodes(3)
        .with_placement(PlacementKind::Block)
        .with_link(LinkSpec::new(100.0, 1.0, 10.0));
    let slow = base
        .clone()
        .with_faults(FaultPlan::none().with_straggler(2, 4.0));
    let b = drive(&base);
    let s = drive(&slow);
    assert_eq!(b.0, s.0, "straggler must not change routing");
    assert_eq!(b.1, s.1, "straggler must not cause failovers");
    assert_eq!(b.2, s.2, "straggler must not change promotions");
    assert!(
        f64::from_bits(s.3) > f64::from_bits(b.3),
        "straggler must inflate total wire time"
    );
    assert_eq!(s, drive(&slow), "straggler scenario must be deterministic");
}

// ---- recovery windows -------------------------------------------------

/// Build a K=2 cluster where experts 1/3/5/7 all live on node 1 and
/// cycle them for `n` lookups over a flat 10 µs link; returns the final
/// net counters plus the number of remote GPU hits observed.
fn drive_node1_cycle(cfg: &ClusterConfig, n: usize) -> moe_beyond::tier::NetStats {
    let mut c = faulty_cluster(cfg);
    for t in 0..n {
        c.lookup(0, [1u8, 3, 5, 7][t % 4], true);
    }
    c.stats().net.expect("cluster backend reports net stats")
}

/// A transient outage window ends: lookups degrade only while the node
/// is down, and service resumes afterwards — unlike a permanent failure,
/// which degrades every remaining lookup.  Both scenarios replay
/// bit-identically.
#[test]
fn down_window_recovery_resumes_service_where_permanent_failure_does_not() {
    let base = ClusterConfig::default()
        .with_nodes(2)
        .with_link(LinkSpec::new(10.0, 0.0, 0.0));
    let windowed = base
        .clone()
        .with_faults(FaultPlan::parse("down:1@20-40").unwrap());
    let permanent = base
        .clone()
        .with_faults(FaultPlan::parse("fail:1@20").unwrap());
    let w = drive_node1_cycle(&windowed, 80);
    let p = drive_node1_cycle(&permanent, 80);
    // exactly the 20 in-window lookups degraded; after recovery node 1
    // serves again, so the permanent failure degrades the other 40 too
    assert_eq!(w.degraded_fetches, 20);
    assert_eq!(p.degraded_fetches, 60);
    // recovery restores the remote-hit stream the dead cluster never got
    assert!(
        w.remote_hits > p.remote_hits,
        "recovered cluster must out-hit the permanently failed one \
         ({} vs {})",
        w.remote_hits,
        p.remote_hits
    );
    assert_eq!(w, drive_node1_cycle(&windowed, 80), "windowed replay diverged");
    assert_eq!(p, drive_node1_cycle(&permanent, 80), "permanent replay diverged");
}

/// Cold vs warm recovery: a down window drops the node's residency
/// (crash-restart misses again), a link flap of the same span keeps it —
/// so the flap run ends with strictly more remote hits while routing the
/// same lookups over the wire.
#[test]
fn link_flap_recovers_warm_where_down_window_recovers_cold() {
    let base = ClusterConfig::default()
        .with_nodes(2)
        .with_link(LinkSpec::new(10.0, 0.0, 0.0));
    let down = base
        .clone()
        .with_faults(FaultPlan::parse("down:1@20-40").unwrap());
    let flap = base
        .clone()
        .with_faults(FaultPlan::parse("flap:1@20-40").unwrap());
    let d = drive_node1_cycle(&down, 80);
    let f = drive_node1_cycle(&flap, 80);
    // identical routing: same lookups went remote, same lookups degraded
    assert_eq!(d.remote_lookups, f.remote_lookups);
    assert_eq!(d.degraded_fetches, f.degraded_fetches);
    // ...but the flap kept node 1's cache warm across the outage
    assert!(
        f.remote_hits > d.remote_hits,
        "warm recovery must out-hit cold recovery ({} vs {})",
        f.remote_hits,
        d.remote_hits
    );
}

/// A degraded-bandwidth episode ends on schedule: wire time is inflated
/// only inside the window, so a longer episode costs strictly more and a
/// healthy run strictly less.
#[test]
fn slow_link_episode_ends_on_schedule() {
    let base = ClusterConfig::default()
        .with_nodes(2)
        .with_link(LinkSpec::new(10.0, 0.0, 0.0));
    let short = base
        .clone()
        .with_faults(FaultPlan::parse("slow:1@10-20*5").unwrap());
    let long = base
        .clone()
        .with_faults(FaultPlan::parse("slow:1@10-30*5").unwrap());
    let h = drive_node1_cycle(&base, 80).wire_us;
    let s = drive_node1_cycle(&short, 80).wire_us;
    let l = drive_node1_cycle(&long, 80).wire_us;
    assert!(h < s, "episode must inflate wire time ({h} vs {s})");
    assert!(s < l, "longer episode must cost strictly more ({s} vs {l})");
}

// ---- timeout / retry / degraded ---------------------------------------

/// With the fetch deadline armed, every lookup served off a straggling
/// owner walks the same deterministic failover order: time out on the
/// rank-0 replica, back off once, serve from rank 1.  The per-attempt
/// accounting is exact and bit-stable across replays.
#[test]
fn timeout_retry_chain_is_deterministic_and_orderly() {
    let cfg = ClusterConfig::default()
        .with_nodes(3)
        .with_replicas(2)
        .with_link(LinkSpec::new(10.0, 0.0, 0.0).with_timeout_us(20.0))
        .with_retry_backoff_us(5.0)
        .with_faults(FaultPlan::parse("straggle:1*10").unwrap());
    let run = || {
        let mut c = faulty_cluster(&cfg);
        // experts 1/4/7 all round-robin to node 1 (the straggler)
        for t in 0..60usize {
            c.lookup(0, [1u8, 4, 7][t % 3], true);
        }
        c.stats().net.expect("cluster backend reports net stats")
    };
    let net = run();
    // every remote serve timed out exactly once on node 1 and was
    // served by the rank-1 replica on node 2 within the deadline
    assert_eq!(net.remote_lookups, 60);
    assert_eq!(net.retries, 60);
    assert_eq!(net.timeout_us, 20.0 * 60.0);
    assert_eq!(net.backoff_us, 5.0 * 60.0); // all first attempts: 5 × 2^0
    assert_eq!(net.failovers, 0, "rank 0 stayed reachable — no failover");
    assert_eq!(net.degraded_fetches, 0, "a replica always served");
    assert_eq!(net, run(), "retry-chain replay diverged");
}

/// When every replica of an expert is unreachable the lookup degrades to
/// the front node and is still served — never a panic — and adding a
/// replica strictly reduces how often that happens under the same plan.
#[test]
fn all_replicas_unreachable_degrades_and_replication_raises_availability() {
    // nodes 1 and 2 are both gone for the first 40 lookups
    let plan = || FaultPlan::parse("down:1@0-40;flap:2@0-40").unwrap();
    let cfg_r = |replicas: usize| {
        ClusterConfig::default()
            .with_nodes(3)
            .with_replicas(replicas)
            .with_link(LinkSpec::new(10.0, 0.0, 0.0))
            .with_faults(plan())
    };
    let drive_mixed = |cfg: &ClusterConfig| {
        let mut c = faulty_cluster(cfg);
        for t in 0..80usize {
            c.lookup(0, ((t * 5) % 64) as u8, true);
        }
        c.stats().net.expect("cluster backend reports net stats")
    };
    let r1 = drive_mixed(&cfg_r(1));
    let r2 = drive_mixed(&cfg_r(2));
    // both degrade while the outage lasts, and only then
    assert!(r1.degraded_fetches > 0);
    assert!(r2.degraded_fetches > 0, "owner-1 experts lost both replicas");
    // R=2 rescues every owner-2 lookup (its rank-1 replica is node 0)
    assert!(
        r2.degraded_fetches < r1.degraded_fetches,
        "replication must strictly reduce degraded fetches ({} vs {})",
        r2.degraded_fetches,
        r1.degraded_fetches
    );
    // deterministic, and the run never panicked while fully partitioned
    assert_eq!(r1, drive_mixed(&cfg_r(1)));
    assert_eq!(r2, drive_mixed(&cfg_r(2)));
}

/// Fault plans that name impossible nodes are rejected at validation,
/// not silently ignored at runtime.
#[test]
fn invalid_fault_plans_rejected_at_validation() {
    // node index out of range
    assert!(ClusterConfig::default()
        .with_nodes(2)
        .with_faults(FaultPlan::none().with_failure(5, 0))
        .validate()
        .is_err());
    // the front node may never fail (it drives decode)
    assert!(ClusterConfig::default()
        .with_nodes(2)
        .with_faults(FaultPlan::none().with_failure(0, 10))
        .validate()
        .is_err());
    // straggler multipliers below 1 would speed the link up
    assert!(ClusterConfig::default()
        .with_nodes(2)
        .with_faults(FaultPlan::none().with_straggler(1, 0.5))
        .validate()
        .is_err());
}
