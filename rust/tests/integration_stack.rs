//! Integration tests: all three layers composing against the real
//! artifact tree (skipped gracefully when `make artifacts` hasn't run).

use moe_beyond::config::{Artifacts, CacheConfig, EamConfig, ServeConfig, SimConfig};
use moe_beyond::coordinator::{EngineConfig, ModelEngine, Request};
use moe_beyond::eval::{eval_trace, EvalAccumulator};
use moe_beyond::moe::Backbone;
use moe_beyond::predictor::{learned, LearnedModel};
use moe_beyond::runtime::PjrtRuntime;
use moe_beyond::sim::sweep::{sweep_capacities, PredictorKind, SweepInputs};
use moe_beyond::trace::store;

fn artifacts() -> Option<Artifacts> {
    let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    root.join("artifacts.json")
        .exists()
        .then(|| Artifacts::discover(&root).unwrap())
}

/// The full offline pipeline: traces -> AOT predictor -> eval metrics.
#[test]
fn predictor_eval_pipeline_beats_baseline() {
    let Some(arts) = artifacts() else { return };
    let rt = PjrtRuntime::cpu().unwrap();
    let model = LearnedModel::load(&rt, &arts).unwrap();
    let traces = store::read_traces(arts.path("traces/test.bin")).unwrap();

    let mut acc = EvalAccumulator::new(64);
    for tr in traces.iter().take(3) {
        let preds = learned::precompute_mode(&model, tr, model.window, 6, true).unwrap();
        eval_trace(&preds, tr, &mut acc);
    }
    // far above the all-negative baseline (acc 0.906, F1 0)
    assert!(acc.accuracy() > 0.92, "accuracy {}", acc.accuracy());
    assert!(acc.micro_f1() > 0.5, "micro f1 {}", acc.micro_f1());
}

/// The simulator end-to-end: learned predictions must clearly beat the
/// EAM heuristic at the paper's 10%-capacity operating point.
#[test]
fn sim_learned_beats_eam_at_low_capacity() {
    let Some(arts) = artifacts() else { return };
    let rt = PjrtRuntime::cpu().unwrap();
    let test = store::read_traces(arts.path("traces/test.bin")).unwrap();
    let test = &test[..6.min(test.len())];
    let fit = store::read_traces(arts.path("traces/train.bin")).unwrap();
    let fit = &fit[..40.min(fit.len())];
    let sim = SimConfig::default();

    let model = LearnedModel::load(&rt, &arts).unwrap();
    let preds: Vec<_> = test
        .iter()
        .map(|tr| learned::precompute(&model, tr, sim.predictor_stride, 6).unwrap())
        .collect();

    let inputs: SweepInputs = SweepInputs {
        test_traces: test,
        fit_traces: fit,
        learned: Some(&preds),
        compiled: None,
        sim,
        eam: EamConfig::default(),
        n_layers: 27,
        n_experts: 64,
    };
    let fracs = [0.10];
    let l = sweep_capacities(PredictorKind::Learned, &fracs, &inputs).unwrap();
    let e = sweep_capacities(PredictorKind::Eam, &fracs, &inputs).unwrap();
    let o = sweep_capacities(PredictorKind::Oracle, &fracs, &inputs).unwrap();
    assert!(
        l.points[0].hit_rate > e.points[0].hit_rate,
        "learned {} <= eam {}",
        l.points[0].hit_rate,
        e.points[0].hit_rate
    );
    assert!(o.points[0].hit_rate >= l.points[0].hit_rate - 1e-9);
}

/// Backbone serving: real HLO decode through the coordinator, conservation
/// of tokens, sane router ids, cache accounting consistent.
#[test]
fn engine_serves_requests_end_to_end() {
    let Some(arts) = artifacts() else { return };
    let rt = PjrtRuntime::cpu().unwrap();
    let cfg = EngineConfig {
        serve: ServeConfig {
            predictor: "learned".into(),
            max_new_tokens: 4,
            ..Default::default()
        },
        cache: CacheConfig::default().with_capacity_frac(0.10, 27, 64),
        sim: SimConfig::default(),
        ..Default::default()
    };
    let mut engine = ModelEngine::load(&rt, &arts, cfg).unwrap();

    let prompt: Vec<i32> = (0..24).map(|i| (i * 7) % 300).collect();
    let resp = engine.process(Request::new(1, prompt, 4)).unwrap();
    assert_eq!(resp.tokens.len(), 4);
    assert!(resp
        .tokens
        .iter()
        .all(|&t| t >= 0 && (t as u32) < arts.world.vocab_size));
    let s = &resp.stats;
    // every (token, layer) ground-truth expert lookup is accounted:
    // (prompt 24 + generated 4) tokens * 27 layers * 6 experts
    assert_eq!(s.cache_hits + s.cache_misses, (24 + 4) * 27 * 6);
    assert!(s.prefetches > 0);

    // second request on a warm engine still conserves counts
    let prompt2: Vec<i32> = (0..16).map(|i| (i * 11) % 300).collect();
    let resp2 = engine.process(Request::new(2, prompt2, 3)).unwrap();
    assert_eq!(resp2.tokens.len(), 3);
    assert_eq!(
        resp2.stats.cache_hits + resp2.stats.cache_misses,
        (16 + 3) * 27 * 6
    );
}

/// Micro-batched decoding shares the cache and completes every stream.
#[test]
fn engine_batch_interleaves() {
    let Some(arts) = artifacts() else { return };
    let rt = PjrtRuntime::cpu().unwrap();
    let cfg = EngineConfig {
        serve: ServeConfig {
            predictor: "none".into(),
            max_new_tokens: 3,
            batch_size: 2,
            ..Default::default()
        },
        cache: CacheConfig::default().with_capacity_frac(0.10, 27, 64),
        sim: SimConfig::default(),
        ..Default::default()
    };
    let mut engine = ModelEngine::load(&rt, &arts, cfg).unwrap();
    let reqs = vec![
        Request::new(1, (0..12).collect(), 3),
        Request::new(2, (50..70).collect(), 3),
    ];
    let out = engine.process_batch(reqs).unwrap();
    assert_eq!(out.len(), 2);
    assert!(out.iter().all(|r| r.tokens.len() == 3));
}

/// Backbone routing from the real HLO stays within the world's expert
/// range and matches the trace format's expectations.
#[test]
fn backbone_routing_is_valid() {
    let Some(arts) = artifacts() else { return };
    let rt = PjrtRuntime::cpu().unwrap();
    let bb = Backbone::load(&rt, &arts).unwrap();
    let tokens: Vec<i32> = (0..30).map(|i| (i * 3) % 500).collect();
    let pre = bb.prefill(&tokens).unwrap();
    let mut kv = pre.kv;
    let mut logits = pre.logits;
    for step in 0..3 {
        let next = moe_beyond::moe::sample_token(&logits, 0.0, &mut moe_beyond::util::Rng::new(7));
        let dec = bb.decode_step(&kv, 30 + step, next).unwrap();
        for l in 0..27 {
            let ids = &dec.router_ids[l * 6..(l + 1) * 6];
            let set: std::collections::BTreeSet<_> = ids.iter().collect();
            assert_eq!(set.len(), 6, "duplicate expert ids at layer {l}");
            assert!(ids.iter().all(|&e| (0..64).contains(&e)));
        }
        kv = dec.kv;
        logits = dec.logits;
    }
}
