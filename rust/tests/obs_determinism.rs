//! Contract tests for the observability layer on the workload surface:
//! two identical seeded drains with active sinks must produce
//! byte-identical trace + metrics exports, an attached sink must not
//! perturb the report, the Chrome trace must mirror the scheduler's
//! invariant counters, and the metric registry must agree with the SLO
//! accumulators it shadows.

use moe_beyond::config::{CacheConfig, EamConfig, SimConfig, TierConfig, WorkloadConfig};
use moe_beyond::memory::{self, ExpertMemory};
use moe_beyond::obs::{ObsSink, SnapValue, DEFAULT_RING_CAP};
use moe_beyond::sim::PredictorKind;
use moe_beyond::tier::TierSpec;
use moe_beyond::trace::{CompiledCorpus, PromptTrace};
use moe_beyond::util::json::Json;
use moe_beyond::workload::{
    report_json, run_workload_obs, synthetic_fit_pool, synthetic_pools, Schedule, SchedPolicy,
    WorkloadInputs, WorkloadReport, WorkloadSpec,
};

const N_LAYERS: usize = 4;
const N_EXPERTS: usize = 64;

struct Fixture {
    spec: WorkloadSpec,
    pools: Vec<Vec<PromptTrace>>,
    fit: Vec<PromptTrace>,
    schedule: Schedule,
}

fn fixture() -> Fixture {
    let spec = WorkloadSpec::example(2, 23, 4.0).with_load(1.5);
    let pools = synthetic_pools(&spec, 4, N_LAYERS as u16, N_EXPERTS);
    let fit = synthetic_fit_pool(&spec, 2, N_LAYERS as u16, N_EXPERTS);
    let schedule = spec.generate(&pools).unwrap();
    Fixture {
        spec,
        pools,
        fit,
        schedule,
    }
}

fn overlap_us() -> f64 {
    WorkloadConfig::default().token_compute_us / N_LAYERS as f64
}

fn flat_memory(cap: usize) -> Box<dyn ExpertMemory> {
    memory::build(
        "lru",
        &CacheConfig::default().with_capacity(cap),
        None,
        &SimConfig::default(),
        N_EXPERTS,
        overlap_us(),
    )
    .unwrap()
}

fn tiered_memory() -> Box<dyn ExpertMemory> {
    let cfg = TierConfig {
        tiers: vec![
            TierSpec::new("gpu", 8, 1.0, 0.0),
            TierSpec::new("host", 64, 100.0, 100.0),
            TierSpec::new("ssd", 256, 1000.0, 0.0),
        ],
        policy: "lru".into(),
    };
    memory::build(
        "lru",
        &CacheConfig::default(),
        Some(&cfg),
        &SimConfig::default(),
        N_EXPERTS,
        overlap_us(),
    )
    .unwrap()
}

fn run_traced(fx: &Fixture, mem: Box<dyn ExpertMemory>, obs: &ObsSink) -> WorkloadReport {
    let cfg = WorkloadConfig {
        max_concurrency: 2,
        policy: SchedPolicy::Fcfs.id().to_string(),
        ..Default::default()
    };
    let sim = SimConfig::default();
    let eam = EamConfig {
        kmeans_clusters: 0,
        ..Default::default()
    };
    let inputs = WorkloadInputs {
        spec: &fx.spec,
        schedule: &fx.schedule,
        pools: &fx.pools,
        fit_traces: &fx.fit,
        learned: None,
        cfg: &cfg,
        sim: &sim,
        eam: &eam,
        n_layers: N_LAYERS,
        n_experts: N_EXPERTS,
    };
    let compiled: Vec<CompiledCorpus> =
        fx.pools.iter().map(|p| CompiledCorpus::compile(p)).collect();
    run_workload_obs(&inputs, PredictorKind::None, mem, &compiled, obs).unwrap()
}

#[test]
fn traced_runs_are_byte_identical() {
    let (fa, fb) = (fixture(), fixture());
    let oa = ObsSink::active(DEFAULT_RING_CAP, "virtual");
    let ob = ObsSink::active(DEFAULT_RING_CAP, "virtual");
    let ra = run_traced(&fa, flat_memory(24), &oa);
    let rb = run_traced(&fb, flat_memory(24), &ob);
    assert_eq!(
        report_json(&ra).to_json_string(),
        report_json(&rb).to_json_string()
    );
    assert_eq!(
        oa.trace_json().unwrap().to_json_string(),
        ob.trace_json().unwrap().to_json_string(),
        "trace JSON must be byte-identical across identical seeded runs"
    );
    assert_eq!(
        oa.metrics_json().unwrap().to_json_string(),
        ob.metrics_json().unwrap().to_json_string(),
        "metrics JSON must be byte-identical across identical seeded runs"
    );
    assert_eq!(
        oa.metrics_prometheus().unwrap(),
        ob.metrics_prometheus().unwrap()
    );
}

#[test]
fn active_sink_does_not_perturb_the_report() {
    let fx = fixture();
    let plain = run_traced(&fx, flat_memory(24), &ObsSink::default());
    let traced = run_traced(
        &fx,
        flat_memory(24),
        &ObsSink::active(DEFAULT_RING_CAP, "virtual"),
    );
    assert_eq!(
        report_json(&plain).to_json_string(),
        report_json(&traced).to_json_string(),
        "attaching a sink must not change the workload report"
    );
}

#[test]
fn chrome_trace_mirrors_scheduler_counters() {
    let fx = fixture();
    let obs = ObsSink::active(DEFAULT_RING_CAP, "virtual");
    let report = run_traced(&fx, flat_memory(24), &obs);
    assert_eq!(obs.dropped_events(), 0, "fixture must fit the ring");

    let j = obs.trace_json().unwrap();
    let meta = j.get("metadata").unwrap();
    assert_eq!(meta.get("clock").unwrap().as_str().unwrap(), "virtual");
    assert_eq!(meta.get("dropped_events").unwrap().as_f64().unwrap(), 0.0);
    let evs = j.get("traceEvents").unwrap().as_arr().unwrap();

    let mut begins = 0u64;
    let mut ends = 0u64;
    let mut steps = 0u64;
    let mut last_ts = f64::NEG_INFINITY;
    for ev in evs {
        let ph = ev.get("ph").unwrap().as_str().unwrap();
        assert!(matches!(ph, "b" | "e" | "X" | "i"), "unexpected ph {ph}");
        assert!(ev.get("name").is_some());
        let ts = ev.get("ts").unwrap().as_f64().unwrap();
        assert!(ts >= last_ts, "virtual-clock events must be time-ordered");
        last_ts = ts;
        match ph {
            "b" => begins += 1,
            "e" => ends += 1,
            "X" => steps += 1,
            _ => {}
        }
    }
    assert_eq!(begins, report.counters.admissions);
    assert_eq!(ends, report.counters.completions);
    assert_eq!(steps, report.counters.steps);
}

#[test]
fn tiered_run_emits_tier_moves_and_registry_mirrors_slo() {
    let fx = fixture();
    let obs = ObsSink::active(DEFAULT_RING_CAP, "virtual");
    let report = run_traced(&fx, tiered_memory(), &obs);

    // the small GPU tier forces promote/demote traffic onto the trace
    let j = obs.trace_json().unwrap();
    let evs = j.get("traceEvents").unwrap().as_arr().unwrap();
    let cat_count = |cat: &str| {
        evs.iter()
            .filter(|e| matches!(e.get("cat"), Some(Json::Str(c)) if c.as_str() == cat))
            .count()
    };
    assert!(cat_count("tier") > 0, "no tier-transition events traced");
    assert!(cat_count("cache") > 0, "no cache-access events traced");

    // the registry's labeled mirrors must agree with the SLO accumulators
    let snap = obs.snapshot().unwrap();
    let counter_sum = |name: &str| -> u64 {
        snap.entries
            .iter()
            .filter(|((n, _), _)| n == name)
            .map(|(_, v)| match v {
                SnapValue::Counter(c) => *c,
                other => panic!("{name} is not a counter: {other:?}"),
            })
            .sum()
    };
    assert_eq!(counter_sum("workload_completions"), report.counters.completions);
    assert_eq!(counter_sum("workload_cache_hits"), report.aggregate.cache.hits);
    assert_eq!(
        counter_sum("workload_cache_misses"),
        report.aggregate.cache.misses
    );
    let latency_count: u64 = snap
        .entries
        .iter()
        .filter(|((n, _), _)| n == "workload_latency_us")
        .map(|(_, v)| match v {
            SnapValue::Hist(h) => h.count(),
            other => panic!("latency is not a histogram: {other:?}"),
        })
        .sum();
    assert_eq!(latency_count, report.counters.completions);
    let gauge = snap
        .entries
        .iter()
        .find(|((n, _), _)| n == "workload_virtual_secs")
        .map(|(_, v)| match v {
            SnapValue::Gauge(g) => *g,
            other => panic!("virtual_secs is not a gauge: {other:?}"),
        })
        .expect("workload_virtual_secs gauge missing");
    assert_eq!(gauge.to_bits(), report.virtual_secs.to_bits());
}
