//! Batched-replay parity suites.
//!
//! The replay hot path was rebuilt around set-level operations
//! (`ExpertMemory::lookup_set`, `CompiledTrace`, the stack-distance
//! capacity sweep).  Every fast path here is held to BYTE-identical
//! output against its scalar/exact twin:
//!
//! * native `lookup_set` (flat and tiered) vs the trait-default scalar
//!   delegation (`memory::ScalarPath`) over full random-trace replays,
//! * the Mattson stack-distance capacity sweep vs the per-capacity
//!   exact replay for LRU/no-prefetch across random capacity grids.

use moe_beyond::cache::{CacheStats, LruCache};
use moe_beyond::config::{CacheConfig, EamConfig, SimConfig, TierConfig};
use moe_beyond::memory::{ExpertMemory, FlatMemory, ScalarPath, TieredMemory};
use moe_beyond::predictor::{NoPrefetch, OraclePredictor};
use moe_beyond::sim::sweep::{
    sweep_capacities_replay_threaded, sweep_capacities_threaded, SweepInputs,
};
use moe_beyond::sim::{PredictorKind, SimEngine};
use moe_beyond::tier::TierSpec;
use moe_beyond::trace::PromptTrace;
use moe_beyond::util::Rng;

fn random_trace(rng: &mut Rng, n_tokens: usize, n_layers: u16, pool: u8) -> PromptTrace {
    let mut experts = Vec::new();
    for _ in 0..n_tokens * n_layers as usize {
        let a = rng.below(pool as usize) as u8;
        let b = (a + 1 + rng.below(pool as usize - 2) as u8) % pool;
        experts.push(a);
        experts.push(b);
    }
    PromptTrace {
        prompt_id: 0,
        n_layers,
        top_k: 2,
        d_emb: 0,
        tokens: vec![0; n_tokens],
        embeddings: vec![],
        experts,
    }
}

fn assert_stats_identical(label: &str, a: &CacheStats, b: &CacheStats) {
    assert_eq!(a.hits, b.hits, "{label}: hits");
    assert_eq!(a.misses, b.misses, "{label}: misses");
    assert_eq!(a.prefetches, b.prefetches, "{label}: prefetches");
    assert_eq!(a.wasted_prefetches, b.wasted_prefetches, "{label}: wasted");
    assert_eq!(a.prediction_hits, b.prediction_hits, "{label}: pred hits");
    assert_eq!(a.prediction_total, b.prediction_total, "{label}: pred total");
    assert_eq!(
        a.transfer_us.to_bits(),
        b.transfer_us.to_bits(),
        "{label}: transfer_us ({} vs {})",
        a.transfer_us,
        b.transfer_us
    );
}

fn run_engine(
    mut memory: Box<dyn ExpertMemory>,
    traces: &[PromptTrace],
    sim: &SimConfig,
    oracle: bool,
) -> (CacheStats, (f64, f64), usize) {
    // residency persists across prompts here on purpose: it exercises
    // lookup_set against a cache in every fill state
    let mut stats = CacheStats::default();
    memory.set_prefetch_budget(sim.prefetch_budget);
    let mut engine = SimEngine::new(memory, sim.clone(), 16);
    for tr in traces {
        if oracle {
            engine.run_prompt(tr, &mut OraclePredictor::new(), &mut stats);
        } else {
            engine.run_prompt(tr, &mut NoPrefetch, &mut stats);
        }
    }
    let marks = engine.memory.cost_marks();
    let resident = engine.memory.resident_count();
    (stats, marks, resident)
}

/// Native flat `lookup_set` vs the trait-default scalar path: full
/// replays over random traces must be byte-identical in every counter,
/// every modeled cost, and the final residency.
#[test]
fn flat_batched_lookup_matches_scalar_delegation() {
    let mut rng = Rng::new(501);
    for case in 0..30 {
        let n_prompts = rng.range(1, 4);
        let traces: Vec<PromptTrace> = (0..n_prompts)
            .map(|_| random_trace(&mut rng, rng.range(4, 40), 3, 16))
            .collect();
        let cap = rng.range(1, 24);
        let sim = SimConfig {
            prefetch_budget: rng.range(1, 6),
            warmup_tokens: rng.below(10),
            ..Default::default()
        };
        let mk_flat = |cap: usize| -> Box<dyn ExpertMemory> {
            Box::new(FlatMemory::new(
                Box::new(LruCache::new(cap)),
                CacheConfig::default().with_capacity(cap),
                16,
                sim.prefetch_budget,
                1_000.0,
            ))
        };
        for oracle in [false, true] {
            let (native, nm, nr) = run_engine(mk_flat(cap), &traces, &sim, oracle);
            let (scalar, sm, sr) =
                run_engine(Box::new(ScalarPath::new(mk_flat(cap))), &traces, &sim, oracle);
            let label = format!("flat case {case} oracle={oracle}");
            assert_stats_identical(&label, &scalar, &native);
            assert_eq!(nm.0.to_bits(), sm.0.to_bits(), "{label}: demand marks");
            assert_eq!(nm.1.to_bits(), sm.1.to_bits(), "{label}: stall marks");
            assert_eq!(nr, sr, "{label}: residency");
        }
    }
}

/// Same guarantee for the tiered backend, including per-tier counters.
#[test]
fn tiered_batched_lookup_matches_scalar_delegation() {
    let mut rng = Rng::new(502);
    for case in 0..30 {
        let n_prompts = rng.range(1, 4);
        let traces: Vec<PromptTrace> = (0..n_prompts)
            .map(|_| random_trace(&mut rng, rng.range(4, 40), 3, 16))
            .collect();
        let cfg = TierConfig {
            tiers: vec![
                TierSpec::new("gpu", rng.range(1, 6), 2.0, 0.0),
                TierSpec::new("host", rng.range(2, 12), 1400.0, 1400.0),
                TierSpec::new("ssd", rng.range(12, 64), 22_000.0, 0.0),
            ],
            policy: "lru".into(),
        };
        let sim = SimConfig {
            prefetch_budget: rng.range(1, 6),
            warmup_tokens: rng.below(10),
            ..Default::default()
        };
        let mk_tiered = || -> Box<dyn ExpertMemory> {
            Box::new(TieredMemory::new(&cfg, 16, sim.prefetch_budget, 1_000.0).unwrap())
        };
        for oracle in [false, true] {
            let mut native_mem = mk_tiered();
            native_mem.set_prefetch_budget(sim.prefetch_budget);
            let mut native_engine = SimEngine::new(native_mem, sim.clone(), 16);
            let mut scalar_engine = SimEngine::new(
                Box::new(ScalarPath::new(mk_tiered())),
                sim.clone(),
                16,
            );
            let mut native = CacheStats::default();
            let mut scalar = CacheStats::default();
            for tr in &traces {
                if oracle {
                    native_engine.run_prompt(tr, &mut OraclePredictor::new(), &mut native);
                    scalar_engine.run_prompt(tr, &mut OraclePredictor::new(), &mut scalar);
                } else {
                    native_engine.run_prompt(tr, &mut NoPrefetch, &mut native);
                    scalar_engine.run_prompt(tr, &mut NoPrefetch, &mut scalar);
                }
            }
            let label = format!("tiered case {case} oracle={oracle}");
            assert_stats_identical(&label, &scalar, &native);
            let (nm, sm) = (
                native_engine.memory.stats(),
                scalar_engine.memory.stats(),
            );
            assert_eq!(
                nm.critical_path_us().to_bits(),
                sm.critical_path_us().to_bits(),
                "{label}: critical path"
            );
            assert_eq!(nm.resident_per_depth, sm.resident_per_depth, "{label}: depth");
            let (nt, st) = (nm.tiers.as_ref().unwrap(), sm.tiers.as_ref().unwrap());
            assert_eq!(nt.served, st.served, "{label}: served");
            assert_eq!(nt.cold, st.cold, "{label}: cold");
            assert_eq!(nt.promotions, st.promotions, "{label}: promotions");
            assert_eq!(nt.demotions, st.demotions, "{label}: demotions");
            assert_eq!(nt.dropped, st.dropped, "{label}: dropped");
        }
    }
}

/// Stack-distance sweep vs exact per-capacity replay: byte-identical
/// `SweepPoint`s for LRU/no-prefetch across random corpora, random
/// capacity fractions, and random warm-up epochs.
#[test]
fn stackdist_sweep_matches_exact_replay() {
    let mut rng = Rng::new(503);
    for case in 0..10 {
        let n_prompts = rng.range(2, 6);
        let test: Vec<PromptTrace> = (0..n_prompts)
            .map(|_| random_trace(&mut rng, rng.range(6, 48), 3, 16))
            .collect();
        let fit: Vec<PromptTrace> = (0..3)
            .map(|_| random_trace(&mut rng, 12, 3, 16))
            .collect();
        let sim = SimConfig {
            warmup_tokens: rng.below(12),
            ..Default::default()
        };
        let inputs = SweepInputs {
            test_traces: &test,
            fit_traces: &fit,
            learned: None,
            sim,
            eam: EamConfig {
                kmeans_clusters: 0,
                ..Default::default()
            },
            n_layers: 3,
            n_experts: 16,
        };
        let mut fracs: Vec<f64> = (0..rng.range(2, 9))
            .map(|_| (rng.range(1, 100) as f64) / 100.0)
            .collect();
        fracs.push(1.0);

        let fast = sweep_capacities_threaded(PredictorKind::None, &fracs, &inputs, 2).unwrap();
        let exact =
            sweep_capacities_replay_threaded(PredictorKind::None, &fracs, &inputs, 2).unwrap();
        assert_eq!(fast.predictor, exact.predictor);
        assert_eq!(fast.points.len(), exact.points.len());
        for (f, e) in fast.points.iter().zip(exact.points.iter()) {
            let label = format!("case {case} frac {}", f.capacity_frac);
            assert_eq!(f.capacity_experts, e.capacity_experts, "{label}");
            assert_eq!(f.hit_rate.to_bits(), e.hit_rate.to_bits(), "{label}: rate");
            assert_eq!(
                f.prediction_hit_rate.to_bits(),
                e.prediction_hit_rate.to_bits(),
                "{label}: pred rate"
            );
            assert_stats_identical(&label, &e.stats, &f.stats);
        }
    }
}
