//! Batched-replay parity suites.
//!
//! The replay hot path was rebuilt around set-level operations
//! (`ExpertMemory::lookup_set`, `CompiledTrace`, the stack-distance
//! capacity sweep).  Every fast path here is held to BYTE-identical
//! output against its scalar/exact twin:
//!
//! * native `lookup_set` (flat and tiered) vs the trait-default scalar
//!   delegation (`memory::ScalarPath`) over full random-trace replays,
//! * the Mattson stack-distance capacity sweep vs the per-capacity
//!   exact replay for LRU/no-prefetch across random capacity grids,
//! * the tiered stack-distance sweep vs the per-cell exact replay across
//!   random tier splits, SSD bandwidths, and warm-up epochs,
//! * batched `predict_layers` vs scalar `predict` for every predictor
//!   kind.

use moe_beyond::cache::{CacheStats, LruCache};
use moe_beyond::config::{CacheConfig, EamConfig, SimConfig, TierConfig};
use moe_beyond::memory::{ExpertMemory, FlatMemory, ScalarPath, TieredMemory};
use moe_beyond::predictor::{
    factory, CachedPredictor, DecodeContext, ExpertPredictor, NoPrefetch, OraclePredictor,
    PredictorParams, TracePredictions,
};
use moe_beyond::sim::sweep::{
    sweep_capacities_replay_threaded, sweep_capacities_threaded, sweep_tiered_replay_threaded,
    sweep_tiered_threaded, SweepInputs,
};
use moe_beyond::sim::{PredictorKind, SimEngine};
use moe_beyond::tier::TierSpec;
use moe_beyond::trace::PromptTrace;
use moe_beyond::util::{ExpertSet, Rng};

fn random_trace(rng: &mut Rng, n_tokens: usize, n_layers: u16, pool: u8) -> PromptTrace {
    let mut experts = Vec::new();
    for _ in 0..n_tokens * n_layers as usize {
        let a = rng.below(pool as usize) as u8;
        let b = (a + 1 + rng.below(pool as usize - 2) as u8) % pool;
        experts.push(a);
        experts.push(b);
    }
    PromptTrace {
        prompt_id: 0,
        n_layers,
        top_k: 2,
        d_emb: 0,
        tokens: vec![0; n_tokens],
        embeddings: vec![],
        experts,
    }
}

fn assert_stats_identical(label: &str, a: &CacheStats, b: &CacheStats) {
    assert_eq!(a.hits, b.hits, "{label}: hits");
    assert_eq!(a.misses, b.misses, "{label}: misses");
    assert_eq!(a.prefetches, b.prefetches, "{label}: prefetches");
    assert_eq!(a.wasted_prefetches, b.wasted_prefetches, "{label}: wasted");
    assert_eq!(a.prediction_hits, b.prediction_hits, "{label}: pred hits");
    assert_eq!(a.prediction_total, b.prediction_total, "{label}: pred total");
    assert_eq!(
        a.transfer_us.to_bits(),
        b.transfer_us.to_bits(),
        "{label}: transfer_us ({} vs {})",
        a.transfer_us,
        b.transfer_us
    );
}

fn run_engine(
    mut memory: Box<dyn ExpertMemory>,
    traces: &[PromptTrace],
    sim: &SimConfig,
    oracle: bool,
) -> (CacheStats, (f64, f64), usize) {
    // residency persists across prompts here on purpose: it exercises
    // lookup_set against a cache in every fill state
    let mut stats = CacheStats::default();
    memory.set_prefetch_budget(sim.prefetch_budget);
    let mut engine = SimEngine::new(memory, sim.clone(), 16);
    for tr in traces {
        if oracle {
            engine.run_prompt(tr, &mut OraclePredictor::new(), &mut stats);
        } else {
            engine.run_prompt(tr, &mut NoPrefetch, &mut stats);
        }
    }
    let marks = engine.memory.cost_marks();
    let resident = engine.memory.resident_count();
    (stats, marks, resident)
}

/// Native flat `lookup_set` vs the trait-default scalar path: full
/// replays over random traces must be byte-identical in every counter,
/// every modeled cost, and the final residency.
#[test]
fn flat_batched_lookup_matches_scalar_delegation() {
    let mut rng = Rng::new(501);
    for case in 0..30 {
        let n_prompts = rng.range(1, 4);
        let traces: Vec<PromptTrace> = (0..n_prompts)
            .map(|_| {
                let n_tokens = rng.range(4, 40);
                random_trace(&mut rng, n_tokens, 3, 16)
            })
            .collect();
        let cap = rng.range(1, 24);
        let sim = SimConfig {
            prefetch_budget: rng.range(1, 6),
            warmup_tokens: rng.below(10),
            ..Default::default()
        };
        let mk_flat = |cap: usize| -> Box<dyn ExpertMemory> {
            Box::new(FlatMemory::new(
                Box::new(LruCache::new(cap)),
                CacheConfig::default().with_capacity(cap),
                16,
                sim.prefetch_budget,
                1_000.0,
            ))
        };
        for oracle in [false, true] {
            let (native, nm, nr) = run_engine(mk_flat(cap), &traces, &sim, oracle);
            let (scalar, sm, sr) =
                run_engine(Box::new(ScalarPath::new(mk_flat(cap))), &traces, &sim, oracle);
            let label = format!("flat case {case} oracle={oracle}");
            assert_stats_identical(&label, &scalar, &native);
            assert_eq!(nm.0.to_bits(), sm.0.to_bits(), "{label}: demand marks");
            assert_eq!(nm.1.to_bits(), sm.1.to_bits(), "{label}: stall marks");
            assert_eq!(nr, sr, "{label}: residency");
        }
    }
}

/// Same guarantee for the tiered backend, including per-tier counters.
#[test]
fn tiered_batched_lookup_matches_scalar_delegation() {
    let mut rng = Rng::new(502);
    for case in 0..30 {
        let n_prompts = rng.range(1, 4);
        let traces: Vec<PromptTrace> = (0..n_prompts)
            .map(|_| {
                let n_tokens = rng.range(4, 40);
                random_trace(&mut rng, n_tokens, 3, 16)
            })
            .collect();
        let cfg = TierConfig {
            tiers: vec![
                TierSpec::new("gpu", rng.range(1, 6), 2.0, 0.0),
                TierSpec::new("host", rng.range(2, 12), 1400.0, 1400.0),
                TierSpec::new("ssd", rng.range(12, 64), 22_000.0, 0.0),
            ],
            policy: "lru".into(),
        };
        let sim = SimConfig {
            prefetch_budget: rng.range(1, 6),
            warmup_tokens: rng.below(10),
            ..Default::default()
        };
        let mk_tiered = || -> Box<dyn ExpertMemory> {
            Box::new(TieredMemory::new(&cfg, 16, sim.prefetch_budget, 1_000.0).unwrap())
        };
        for oracle in [false, true] {
            let mut native_mem = mk_tiered();
            native_mem.set_prefetch_budget(sim.prefetch_budget);
            let mut native_engine = SimEngine::new(native_mem, sim.clone(), 16);
            let mut scalar_engine = SimEngine::new(
                Box::new(ScalarPath::new(mk_tiered())),
                sim.clone(),
                16,
            );
            let mut native = CacheStats::default();
            let mut scalar = CacheStats::default();
            for tr in &traces {
                if oracle {
                    native_engine.run_prompt(tr, &mut OraclePredictor::new(), &mut native);
                    scalar_engine.run_prompt(tr, &mut OraclePredictor::new(), &mut scalar);
                } else {
                    native_engine.run_prompt(tr, &mut NoPrefetch, &mut native);
                    scalar_engine.run_prompt(tr, &mut NoPrefetch, &mut scalar);
                }
            }
            let label = format!("tiered case {case} oracle={oracle}");
            assert_stats_identical(&label, &scalar, &native);
            let (nm, sm) = (
                native_engine.memory.stats(),
                scalar_engine.memory.stats(),
            );
            assert_eq!(
                nm.critical_path_us().to_bits(),
                sm.critical_path_us().to_bits(),
                "{label}: critical path"
            );
            assert_eq!(nm.resident_per_depth, sm.resident_per_depth, "{label}: depth");
            let (nt, st) = (nm.tiers.as_ref().unwrap(), sm.tiers.as_ref().unwrap());
            assert_eq!(nt.served, st.served, "{label}: served");
            assert_eq!(nt.cold, st.cold, "{label}: cold");
            assert_eq!(nt.promotions, st.promotions, "{label}: promotions");
            assert_eq!(nt.demotions, st.demotions, "{label}: demotions");
            assert_eq!(nt.dropped, st.dropped, "{label}: dropped");
        }
    }
}

/// Stack-distance sweep vs exact per-capacity replay: byte-identical
/// `SweepPoint`s for LRU/no-prefetch across random corpora, random
/// capacity fractions, and random warm-up epochs.
#[test]
fn stackdist_sweep_matches_exact_replay() {
    let mut rng = Rng::new(503);
    for case in 0..10 {
        let n_prompts = rng.range(2, 6);
        let test: Vec<PromptTrace> = (0..n_prompts)
            .map(|_| {
                let n_tokens = rng.range(6, 48);
                random_trace(&mut rng, n_tokens, 3, 16)
            })
            .collect();
        let fit: Vec<PromptTrace> = (0..3)
            .map(|_| random_trace(&mut rng, 12, 3, 16))
            .collect();
        let sim = SimConfig {
            warmup_tokens: rng.below(12),
            ..Default::default()
        };
        let inputs: SweepInputs = SweepInputs {
            test_traces: &test,
            fit_traces: &fit,
            learned: None,
            compiled: None,
            sim,
            eam: EamConfig {
                kmeans_clusters: 0,
                ..Default::default()
            },
            n_layers: 3,
            n_experts: 16,
        };
        let mut fracs: Vec<f64> = (0..rng.range(2, 9))
            .map(|_| (rng.range(1, 100) as f64) / 100.0)
            .collect();
        fracs.push(1.0);

        let fast = sweep_capacities_threaded(PredictorKind::None, &fracs, &inputs, 2).unwrap();
        let exact =
            sweep_capacities_replay_threaded(PredictorKind::None, &fracs, &inputs, 2).unwrap();
        assert_eq!(fast.predictor, exact.predictor);
        assert_eq!(fast.points.len(), exact.points.len());
        for (f, e) in fast.points.iter().zip(exact.points.iter()) {
            let label = format!("case {case} frac {}", f.capacity_frac);
            assert_eq!(f.capacity_experts, e.capacity_experts, "{label}");
            assert_eq!(f.hit_rate.to_bits(), e.hit_rate.to_bits(), "{label}: rate");
            assert_eq!(
                f.prediction_hit_rate.to_bits(),
                e.prediction_hit_rate.to_bits(),
                "{label}: pred rate"
            );
            assert_stats_identical(&label, &e.stats, &f.stats);
        }
    }
}

/// Tiered stack-distance sweep vs the exact per-cell replay:
/// byte-identical `TierSweepPoint`s — every CacheStats counter, every
/// per-tier serve/demotion/drop counter, and the modeled critical path —
/// across random tier splits, random (integer) SSD fetch costs, random
/// warm-up epochs, and both a writeback-free hierarchy and one whose
/// writeback DMA provably fits the overlap window (the stall-free gate).
#[test]
fn tiered_stackdist_sweep_matches_exact_replay() {
    let mut rng = Rng::new(504);
    for case in 0..8 {
        let n_prompts = rng.range(2, 6);
        let test: Vec<PromptTrace> = (0..n_prompts)
            .map(|_| {
                let n_tokens = rng.range(6, 48);
                random_trace(&mut rng, n_tokens, 3, 16)
            })
            .collect();
        let fit: Vec<PromptTrace> = (0..3)
            .map(|_| random_trace(&mut rng, 12, 3, 16))
            .collect();
        let sim = SimConfig {
            warmup_tokens: rng.below(12),
            ..Default::default()
        };
        let inputs: SweepInputs = SweepInputs {
            test_traces: &test,
            fit_traces: &fit,
            learned: None,
            compiled: None,
            sim,
            eam: EamConfig {
                kmeans_clusters: 0,
                ..Default::default()
            },
            n_layers: 3,
            n_experts: 16,
        };
        // integer-valued costs keep every float total exactly
        // representable, so to_bits comparisons are meaningful
        let host_wb = if case % 2 == 0 { 0.0 } else { 100.0 }; // 100·2 ≤ 1000 overlap
        let base = TierConfig {
            tiers: vec![
                TierSpec::new("gpu", 1, 2.0, 0.0),
                TierSpec::new("host", 1, 1400.0, host_wb),
                TierSpec::new("ssd", 48, 22_000.0, 0.0),
            ],
            policy: "lru".into(),
        };
        let gpu: Vec<f64> = (0..rng.range(2, 5))
            .map(|_| (rng.range(1, 90) as f64) / 100.0)
            .collect();
        let host: Vec<f64> = (0..rng.range(1, 4))
            .map(|_| (rng.range(1, 100) as f64) / 100.0)
            .collect();
        // SSD cost must stay >= the host fetch (TierConfig::validate
        // orders tiers fastest-to-slowest)
        let ssd: Vec<f64> = (0..rng.range(1, 4))
            .map(|_| rng.range(1400, 40_000) as f64)
            .collect();

        for threads in [1usize, 4] {
            let fast = sweep_tiered_threaded(
                PredictorKind::None, &gpu, &host, &ssd, &inputs, &base, 1_000.0, threads,
            )
            .unwrap();
            let exact = sweep_tiered_replay_threaded(
                PredictorKind::None, &gpu, &host, &ssd, &inputs, &base, 1_000.0, threads,
            )
            .unwrap();
            assert_eq!(fast.len(), exact.len());
            for (f, e) in fast.iter().zip(exact.iter()) {
                let label = format!(
                    "case {case} threads {threads} gpu {} host {} ssd {}",
                    f.gpu_frac, f.host_frac, f.ssd_us_per_expert
                );
                assert_stats_identical(&label, &e.stats, &f.stats);
                assert_eq!(
                    f.gpu_hit_rate.to_bits(),
                    e.gpu_hit_rate.to_bits(),
                    "{label}: gpu hit rate"
                );
                assert_eq!(
                    f.deep_miss_rate.to_bits(),
                    e.deep_miss_rate.to_bits(),
                    "{label}: deep miss rate"
                );
                assert_eq!(
                    f.critical_path_us.to_bits(),
                    e.critical_path_us.to_bits(),
                    "{label}: critical path ({} vs {})",
                    f.critical_path_us,
                    e.critical_path_us
                );
                assert_eq!(f.tiers.served, e.tiers.served, "{label}: served");
                assert_eq!(f.tiers.cold, e.tiers.cold, "{label}: cold");
                assert_eq!(f.tiers.promotions, e.tiers.promotions, "{label}: promotions");
                assert_eq!(
                    f.tiers.prefetch_promotions, e.tiers.prefetch_promotions,
                    "{label}: prefetch promotions"
                );
                assert_eq!(f.tiers.demotions, e.tiers.demotions, "{label}: demotions");
                assert_eq!(f.tiers.dropped, e.tiers.dropped, "{label}: dropped");
            }
        }
    }
}

/// A hierarchy whose writeback DMA can exceed the overlap window is NOT
/// eligible for the analytic path — the dispatcher must fall back to the
/// exact replay, so both entry points still agree (trivially, but this
/// pins the gate itself).
#[test]
fn stall_prone_config_falls_back_to_exact_replay() {
    let mut rng = Rng::new(505);
    let test: Vec<PromptTrace> = (0..3)
        .map(|_| random_trace(&mut rng, 24, 3, 16))
        .collect();
    let fit = vec![random_trace(&mut rng, 12, 3, 16)];
    let inputs: SweepInputs = SweepInputs {
        test_traces: &test,
        fit_traces: &fit,
        learned: None,
        compiled: None,
        sim: SimConfig::default(),
        eam: EamConfig {
            kmeans_clusters: 0,
            ..Default::default()
        },
        n_layers: 3,
        n_experts: 16,
    };
    // host writeback 1400 × top-2 cells > 1000 overlap: stall possible
    let base = TierConfig {
        tiers: vec![
            TierSpec::new("gpu", 1, 2.0, 0.0),
            TierSpec::new("host", 1, 1400.0, 1400.0),
            TierSpec::new("ssd", 48, 22_000.0, 0.0),
        ],
        policy: "lru".into(),
    };
    let fast = sweep_tiered_threaded(
        PredictorKind::None, &[0.05, 0.3], &[0.1], &[22_000.0], &inputs, &base, 1_000.0, 2,
    )
    .unwrap();
    let exact = sweep_tiered_replay_threaded(
        PredictorKind::None, &[0.05, 0.3], &[0.1], &[22_000.0], &inputs, &base, 1_000.0, 2,
    )
    .unwrap();
    for (f, e) in fast.iter().zip(exact.iter()) {
        // the replay CAN stall here, and the dispatcher must have taken
        // the replay: bit-identical including any stall time
        assert_eq!(f.critical_path_us.to_bits(), e.critical_path_us.to_bits());
        assert_eq!(f.tiers.demotions, e.tiers.demotions);
    }
}

/// `predict_layers` == back-to-back scalar `predict` calls (no
/// intervening observations) for EVERY predictor kind, across random
/// traces and observation histories.
#[test]
fn predict_layers_matches_scalar_for_every_kind() {
    let n_layers = 3usize;
    let n_experts = 16usize;
    let mut rng = Rng::new(506);
    let fit: Vec<PromptTrace> = (0..6)
        .map(|_| random_trace(&mut rng, 12, n_layers as u16, 16))
        .collect();
    let eam = EamConfig {
        kmeans_clusters: 0,
        ..Default::default()
    };
    let params = PredictorParams {
        eam: &eam,
        predict_top_k: 4,
        n_layers,
        n_experts,
        fit_traces: &fit,
    };

    for kind in PredictorKind::ALL {
        for case in 0..6 {
            let n_tokens = rng.range(4, 24);
            let tr = random_trace(&mut rng, n_tokens, n_layers as u16, 16);
            // synthetic learned predictions: random per-(token, layer) sets
            let preds: TracePredictions = TracePredictions {
                n_layers,
                sets: (0..tr.n_tokens())
                    .map(|_| {
                        (0..n_layers)
                            .map(|_| {
                                ExpertSet::from_ids(
                                    (0..3).map(|_| rng.below(n_experts) as u8),
                                )
                            })
                            .collect()
                    })
                    .collect(),
                logits: vec![Vec::new(); tr.n_tokens()],
                n_experts,
            };
            let mut p: Box<dyn ExpertPredictor + '_> = match kind {
                PredictorKind::Learned => Box::new(CachedPredictor::new(&preds)),
                _ => factory::build(kind, &params).unwrap(),
            };
            p.begin_prompt(&tr);
            for t in 0..tr.n_tokens() {
                let ctx = DecodeContext { trace: &tr, t };
                // scalar predictions are idempotent between observations,
                // so one instance can answer both ways
                let scalar: Vec<ExpertSet> =
                    (0..n_layers).map(|l| p.predict(&ctx, l)).collect();
                let mut batched = vec![ExpertSet::EMPTY; n_layers];
                p.predict_layers(&ctx, 0..n_layers, &mut batched);
                assert_eq!(
                    scalar, batched,
                    "kind {kind:?} case {case} token {t}: batched != scalar"
                );
                for l in 0..n_layers {
                    p.observe(&ctx, l, tr.expert_set(t, l));
                }
            }
            p.end_prompt(&tr);
        }
    }
}
