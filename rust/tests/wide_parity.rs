//! Multi-word `ExpertSet` parity suites.
//!
//! `ExpertSet` grew from a single `u64` to a const-generic `[u64; N]`
//! bitset.  These tests pin the wide paths two ways:
//!
//! * every set operation (insert/remove/contains, the branch-free
//!   algebra, `top_k_mask_f32`, construction helpers, iteration order)
//!   against a naive `BTreeSet<u8>` / `Vec<bool>` reference, randomized
//!   over N = 1, 2 and 3 word widths,
//! * a 160-expert (3-word) world end-to-end: the set-level replay fast
//!   path vs the `ScalarPath` per-id reference (flat and tiered), the
//!   stack-distance capacity sweep vs the exact per-capacity replay,
//!   the analytic tiered sweep vs the per-cell replay, and a full
//!   workload-simulator run — all byte-identical / deterministic, with
//!   ids beyond the first word provably exercised.

use std::collections::BTreeSet;

use moe_beyond::cache::{CacheStats, LruCache};
use moe_beyond::config::{CacheConfig, EamConfig, SimConfig, TierConfig, WorkloadConfig};
use moe_beyond::memory::{self, ExpertMemory, FlatMemory, ScalarPath, TieredMemory};
use moe_beyond::predictor::{NoPrefetch, OraclePredictor};
use moe_beyond::sim::sweep::{
    sweep_capacities_replay_threaded, sweep_capacities_threaded, sweep_tiered_replay_threaded,
    sweep_tiered_threaded, SweepInputs,
};
use moe_beyond::sim::{PredictorKind, SimEngine};
use moe_beyond::tier::TierSpec;
use moe_beyond::trace::PromptTrace;
use moe_beyond::util::{words_for, ExpertSet, Rng};
use moe_beyond::workload::{
    run_workload, synthetic_fit_pool, synthetic_pools, WorkloadInputs, WorkloadSpec,
};

/// The wide world under test: 160 experts need 3 words.
const WIDE_EXPERTS: usize = 160;
const WIDE: usize = 3;
const _: () = assert!(words_for(WIDE_EXPERTS) == WIDE);

// ---------------------------------------------------------------------
// Part 1: op-level parity against naive references, N = 1, 2, 3
// ---------------------------------------------------------------------

fn naive_from(model: &BTreeSet<u8>) -> Vec<u8> {
    model.iter().copied().collect()
}

/// Mirror of the documented `top_k_mask_f32` contract, written the slow
/// way: repeated argmax over a `Vec<bool>` taken-mask, ties to the lower
/// index, NaNs never win, stop when no finite candidate remains.
fn naive_top_k(xs: &[f32], k: usize) -> Vec<u8> {
    let k = k.min(xs.len());
    let mut taken = vec![false; xs.len()];
    for _ in 0..k {
        let mut best = usize::MAX;
        let mut best_v = f32::NEG_INFINITY;
        for (i, &v) in xs.iter().enumerate() {
            if !taken[i] && v > best_v {
                best = i;
                best_v = v;
            }
        }
        if best == usize::MAX {
            break;
        }
        taken[best] = true;
    }
    (0..xs.len()).filter(|&i| taken[i]).map(|i| i as u8).collect()
}

/// Randomized mutate-and-compare: an `ExpertSet<N>` shadowed by a
/// `BTreeSet<u8>` model through a long insert/remove sequence.
fn ops_parity<const N: usize>(seed: u64) {
    let cap = ExpertSet::<N>::CAPACITY;
    let mut rng = Rng::new(seed);
    for _case in 0..40 {
        let mut set = ExpertSet::<N>::new();
        let mut model: BTreeSet<u8> = BTreeSet::new();
        assert!(set.is_empty());
        for _op in 0..300 {
            let id = rng.below(cap) as u8;
            if rng.f64() < 0.6 {
                set.insert(id);
                model.insert(id);
            } else {
                set.remove(id);
                model.remove(&id);
            }
            assert_eq!(set.contains(id), model.contains(&id), "contains({id})");
            assert_eq!(set.len() as usize, model.len(), "len after op on {id}");
            assert_eq!(set.is_empty(), model.is_empty());
        }
        // iteration order is ascending and complete
        assert_eq!(set.to_vec(), naive_from(&model), "to_vec order");
        assert_eq!(set.iter().collect::<Vec<u8>>(), naive_from(&model));
        // construction round-trips
        assert_eq!(ExpertSet::<N>::from_ids(model.iter().copied()), set);
        assert_eq!(model.iter().copied().collect::<ExpertSet<N>>(), set);
        assert_eq!(ExpertSet::<N>::from_words(*set.as_words()), set);
    }
}

/// Randomized algebra parity: union/intersect/difference/overlap/jaccard
/// against their set-theoretic references.
fn algebra_parity<const N: usize>(seed: u64) {
    let cap = ExpertSet::<N>::CAPACITY;
    let mut rng = Rng::new(seed);
    for _case in 0..200 {
        let n_a = rng.below(cap + 1);
        let n_b = rng.below(cap + 1);
        let ma: BTreeSet<u8> = (0..n_a).map(|_| rng.below(cap) as u8).collect();
        let mb: BTreeSet<u8> = (0..n_b).map(|_| rng.below(cap) as u8).collect();
        let a = ExpertSet::<N>::from_ids(ma.iter().copied());
        let b = ExpertSet::<N>::from_ids(mb.iter().copied());

        let uni: Vec<u8> = ma.union(&mb).copied().collect();
        let inter: Vec<u8> = ma.intersection(&mb).copied().collect();
        let diff: Vec<u8> = ma.difference(&mb).copied().collect();
        assert_eq!(a.union(b).to_vec(), uni, "union");
        assert_eq!(a.intersect(b).to_vec(), inter, "intersect");
        assert_eq!(a.difference(b).to_vec(), diff, "difference");
        assert_eq!(a.overlap(b) as usize, inter.len(), "overlap");
        let want_jaccard = if uni.is_empty() {
            1.0
        } else {
            inter.len() as f64 / uni.len() as f64
        };
        assert_eq!(a.jaccard(b).to_bits(), want_jaccard.to_bits(), "jaccard");
    }
}

/// Randomized `top_k_mask_f32` parity, including duplicate values
/// (quantized grid → lower-index tie breaks matter) and NaN logits.
fn top_k_parity<const N: usize>(seed: u64) {
    let cap = ExpertSet::<N>::CAPACITY;
    let mut rng = Rng::new(seed);
    for _case in 0..200 {
        let n = rng.range(1, cap + 1);
        let xs: Vec<f32> = (0..n)
            .map(|_| {
                if rng.f64() < 0.05 {
                    f32::NAN
                } else {
                    // coarse grid forces frequent exact ties
                    (rng.below(8) as f32) - 4.0
                }
            })
            .collect();
        // k can exceed xs.len(): the mask must saturate, not panic
        let k = rng.below(cap + 8);
        let mask: ExpertSet<N> = ExpertSet::top_k_mask_f32(&xs, k);
        assert_eq!(mask.to_vec(), naive_top_k(&xs, k), "k={k} n={n}");
    }
}

#[test]
fn wide_ops_match_naive_reference() {
    ops_parity::<1>(7001);
    ops_parity::<2>(7002);
    ops_parity::<3>(7003);
}

#[test]
fn wide_algebra_matches_naive_reference() {
    algebra_parity::<1>(7101);
    algebra_parity::<2>(7102);
    algebra_parity::<3>(7103);
}

#[test]
fn wide_top_k_matches_naive_argmax() {
    top_k_parity::<1>(7201);
    top_k_parity::<2>(7202);
    top_k_parity::<3>(7203);
}

#[test]
fn wide_all_fills_exact_prefix() {
    fn check<const N: usize>() {
        for n in [0usize, 1, 63, 64, 65, 127, 128, 129, ExpertSet::<N>::CAPACITY] {
            if n > ExpertSet::<N>::CAPACITY {
                continue;
            }
            let s: ExpertSet<N> = ExpertSet::all(n as u16);
            assert_eq!(s.len() as usize, n, "all({n}) len");
            assert_eq!(s.to_vec(), (0..n as u8).collect::<Vec<u8>>(), "all({n}) ids");
        }
    }
    check::<1>();
    check::<2>();
    check::<3>();
}

// ---------------------------------------------------------------------
// Part 2: 160-expert (3-word) world end-to-end
// ---------------------------------------------------------------------

/// Random trace whose ids span the whole `0..n_experts` range, so a
/// wide world routinely routes to ids ≥ 64 (words 1 and 2).
fn random_wide_trace(rng: &mut Rng, n_tokens: usize, n_layers: u16, n_experts: usize) -> PromptTrace {
    let mut experts = Vec::new();
    for _ in 0..n_tokens * n_layers as usize {
        let a = rng.below(n_experts);
        let b = (a + 1 + rng.below(n_experts - 2)) % n_experts;
        experts.push(a as u8);
        experts.push(b as u8);
    }
    PromptTrace {
        prompt_id: 0,
        n_layers,
        top_k: 2,
        d_emb: 0,
        tokens: vec![0; n_tokens],
        embeddings: vec![],
        experts,
    }
}

fn assert_stats_identical(label: &str, a: &CacheStats, b: &CacheStats) {
    assert_eq!(a.hits, b.hits, "{label}: hits");
    assert_eq!(a.misses, b.misses, "{label}: misses");
    assert_eq!(a.prefetches, b.prefetches, "{label}: prefetches");
    assert_eq!(a.wasted_prefetches, b.wasted_prefetches, "{label}: wasted");
    assert_eq!(a.prediction_hits, b.prediction_hits, "{label}: pred hits");
    assert_eq!(a.prediction_total, b.prediction_total, "{label}: pred total");
    assert_eq!(
        a.transfer_us.to_bits(),
        b.transfer_us.to_bits(),
        "{label}: transfer_us ({} vs {})",
        a.transfer_us,
        b.transfer_us
    );
}

fn run_engine_wide(
    mut memory: Box<dyn ExpertMemory<WIDE>>,
    traces: &[PromptTrace],
    sim: &SimConfig,
    oracle: bool,
) -> (CacheStats, (f64, f64), usize) {
    let mut stats = CacheStats::default();
    memory.set_prefetch_budget(sim.prefetch_budget);
    let mut engine = SimEngine::new(memory, sim.clone(), WIDE_EXPERTS);
    for tr in traces {
        if oracle {
            engine.run_prompt(tr, &mut OraclePredictor::new(), &mut stats);
        } else {
            engine.run_prompt(tr, &mut NoPrefetch, &mut stats);
        }
    }
    let marks = engine.memory.cost_marks();
    let resident = engine.memory.resident_count();
    (stats, marks, resident)
}

/// 3-word flat replay: native `lookup_set` vs scalar delegation must be
/// byte-identical, exactly as the single-word suite guarantees.
#[test]
fn wide_flat_batched_lookup_matches_scalar_delegation() {
    let mut rng = Rng::new(601);
    for case in 0..12 {
        let traces: Vec<PromptTrace> = (0..rng.range(1, 4))
            .map(|_| {
                let n_tokens = rng.range(4, 40);
                random_wide_trace(&mut rng, n_tokens, 3, WIDE_EXPERTS)
            })
            .collect();
        assert!(
            traces.iter().any(|tr| tr.experts.iter().any(|&e| e >= 64)),
            "wide traces must route beyond word 0"
        );
        let cap = rng.range(4, 3 * WIDE_EXPERTS / 2);
        let sim = SimConfig {
            prefetch_budget: rng.range(1, 6),
            warmup_tokens: rng.below(10),
            ..Default::default()
        };
        let mk_flat = |cap: usize| -> Box<dyn ExpertMemory<WIDE>> {
            Box::new(FlatMemory::<WIDE>::new(
                Box::new(LruCache::new(cap)),
                CacheConfig::default().with_capacity(cap),
                WIDE_EXPERTS,
                sim.prefetch_budget,
                1_000.0,
            ))
        };
        for oracle in [false, true] {
            let (native, nm, nr) = run_engine_wide(mk_flat(cap), &traces, &sim, oracle);
            let (scalar, sm, sr) =
                run_engine_wide(Box::new(ScalarPath::new(mk_flat(cap))), &traces, &sim, oracle);
            let label = format!("wide flat case {case} oracle={oracle}");
            assert_stats_identical(&label, &scalar, &native);
            assert_eq!(nm.0.to_bits(), sm.0.to_bits(), "{label}: demand marks");
            assert_eq!(nm.1.to_bits(), sm.1.to_bits(), "{label}: stall marks");
            assert_eq!(nr, sr, "{label}: residency");
        }
    }
}

/// Same guarantee for the 3-word tiered backend.
#[test]
fn wide_tiered_batched_lookup_matches_scalar_delegation() {
    let mut rng = Rng::new(602);
    for case in 0..12 {
        let traces: Vec<PromptTrace> = (0..rng.range(1, 4))
            .map(|_| {
                let n_tokens = rng.range(4, 40);
                random_wide_trace(&mut rng, n_tokens, 3, WIDE_EXPERTS)
            })
            .collect();
        let cfg = TierConfig {
            tiers: vec![
                TierSpec::new("gpu", rng.range(4, 24), 2.0, 0.0),
                TierSpec::new("host", rng.range(16, 64), 1400.0, 1400.0),
                TierSpec::new("ssd", 3 * WIDE_EXPERTS, 22_000.0, 0.0),
            ],
            policy: "lru".into(),
        };
        let sim = SimConfig {
            prefetch_budget: rng.range(1, 6),
            warmup_tokens: rng.below(10),
            ..Default::default()
        };
        let mk_tiered = || -> Box<dyn ExpertMemory<WIDE>> {
            Box::new(
                TieredMemory::<WIDE>::new(&cfg, WIDE_EXPERTS, sim.prefetch_budget, 1_000.0)
                    .unwrap(),
            )
        };
        for oracle in [false, true] {
            let (native, nm, nr) = run_engine_wide(mk_tiered(), &traces, &sim, oracle);
            let (scalar, sm, sr) =
                run_engine_wide(Box::new(ScalarPath::new(mk_tiered())), &traces, &sim, oracle);
            let label = format!("wide tiered case {case} oracle={oracle}");
            assert_stats_identical(&label, &scalar, &native);
            assert_eq!(nm.0.to_bits(), sm.0.to_bits(), "{label}: demand marks");
            assert_eq!(nm.1.to_bits(), sm.1.to_bits(), "{label}: stall marks");
            assert_eq!(nr, sr, "{label}: residency");
        }
    }
}

fn wide_sweep_corpus(rng: &mut Rng) -> (Vec<PromptTrace>, Vec<PromptTrace>) {
    let test: Vec<PromptTrace> = (0..rng.range(2, 5))
        .map(|_| {
            let n_tokens = rng.range(6, 40);
            random_wide_trace(rng, n_tokens, 3, WIDE_EXPERTS)
        })
        .collect();
    let fit: Vec<PromptTrace> = (0..3)
        .map(|_| random_wide_trace(rng, 12, 3, WIDE_EXPERTS))
        .collect();
    (test, fit)
}

/// 160-expert stack-distance capacity sweep vs the exact per-capacity
/// replay: byte-identical `SweepPoint`s.
#[test]
fn wide_stackdist_sweep_matches_exact_replay() {
    let mut rng = Rng::new(603);
    for case in 0..6 {
        let (test, fit) = wide_sweep_corpus(&mut rng);
        let sim = SimConfig {
            warmup_tokens: rng.below(12),
            ..Default::default()
        };
        let inputs: SweepInputs<WIDE> = SweepInputs {
            test_traces: &test,
            fit_traces: &fit,
            learned: None,
            compiled: None,
            sim,
            eam: EamConfig {
                kmeans_clusters: 0,
                ..Default::default()
            },
            n_layers: 3,
            n_experts: WIDE_EXPERTS,
        };
        let mut fracs: Vec<f64> = (0..rng.range(2, 7))
            .map(|_| (rng.range(1, 100) as f64) / 100.0)
            .collect();
        fracs.push(1.0);

        let fast = sweep_capacities_threaded(PredictorKind::None, &fracs, &inputs, 2).unwrap();
        let exact =
            sweep_capacities_replay_threaded(PredictorKind::None, &fracs, &inputs, 2).unwrap();
        assert_eq!(fast.points.len(), exact.points.len());
        for (f, e) in fast.points.iter().zip(exact.points.iter()) {
            let label = format!("wide case {case} frac {}", f.capacity_frac);
            assert_eq!(f.capacity_experts, e.capacity_experts, "{label}");
            assert_eq!(f.hit_rate.to_bits(), e.hit_rate.to_bits(), "{label}: rate");
            assert_stats_identical(&label, &e.stats, &f.stats);
        }
    }
}

/// 160-expert analytic tiered sweep vs the per-cell exact replay.
#[test]
fn wide_tiered_sweep_matches_exact_replay() {
    let mut rng = Rng::new(604);
    for case in 0..4 {
        let (test, fit) = wide_sweep_corpus(&mut rng);
        let sim = SimConfig {
            warmup_tokens: rng.below(12),
            ..Default::default()
        };
        let inputs: SweepInputs<WIDE> = SweepInputs {
            test_traces: &test,
            fit_traces: &fit,
            learned: None,
            compiled: None,
            sim,
            eam: EamConfig {
                kmeans_clusters: 0,
                ..Default::default()
            },
            n_layers: 3,
            n_experts: WIDE_EXPERTS,
        };
        let base = TierConfig {
            tiers: vec![
                TierSpec::new("gpu", 1, 2.0, 0.0),
                TierSpec::new("host", 1, 1400.0, 0.0),
                TierSpec::new("ssd", 3 * WIDE_EXPERTS, 22_000.0, 0.0),
            ],
            policy: "lru".into(),
        };
        let gpu: Vec<f64> = (0..2).map(|_| (rng.range(1, 90) as f64) / 100.0).collect();
        let host: Vec<f64> = (0..2).map(|_| (rng.range(1, 100) as f64) / 100.0).collect();
        let ssd = [rng.range(1400, 40_000) as f64];

        let fast = sweep_tiered_threaded(
            PredictorKind::None, &gpu, &host, &ssd, &inputs, &base, 1_000.0, 2,
        )
        .unwrap();
        let exact = sweep_tiered_replay_threaded(
            PredictorKind::None, &gpu, &host, &ssd, &inputs, &base, 1_000.0, 2,
        )
        .unwrap();
        assert_eq!(fast.len(), exact.len());
        for (f, e) in fast.iter().zip(exact.iter()) {
            let label = format!(
                "wide case {case} gpu {} host {} ssd {}",
                f.gpu_frac, f.host_frac, f.ssd_us_per_expert
            );
            assert_stats_identical(&label, &e.stats, &f.stats);
            assert_eq!(
                f.critical_path_us.to_bits(),
                e.critical_path_us.to_bits(),
                "{label}: critical path"
            );
            assert_eq!(f.tiers.served, e.tiers.served, "{label}: served");
            assert_eq!(f.tiers.demotions, e.tiers.demotions, "{label}: demotions");
            assert_eq!(f.tiers.dropped, e.tiers.dropped, "{label}: dropped");
        }
    }
}

/// 160-expert workload simulator: a full multi-tenant run completes,
/// conserves scheduler work, actually routes beyond word 0, and is
/// bitwise deterministic across identical runs.
#[test]
fn wide_workload_sim_runs_and_is_deterministic() {
    let n_layers = 3usize;
    let spec = WorkloadSpec::example(3, 7, 6.0).with_load(2.0);
    let pools = synthetic_pools(&spec, 4, n_layers as u16, WIDE_EXPERTS);
    let fit = synthetic_fit_pool(&spec, 3, n_layers as u16, WIDE_EXPERTS);
    assert!(
        pools.iter().flatten().any(|tr| tr.experts.iter().any(|&e| e >= 128)),
        "160-expert synthetic pools must reach the third word"
    );
    let schedule = spec.generate(&pools).unwrap();
    let sim = SimConfig::default();
    let eam = EamConfig {
        kmeans_clusters: 0,
        ..Default::default()
    };
    let cfg = WorkloadConfig::default();
    let mk_mem = || -> Box<dyn ExpertMemory<WIDE>> {
        let cap = (n_layers * WIDE_EXPERTS) / 10;
        memory::build(
            "lru",
            &CacheConfig::default().with_capacity(cap),
            None,
            &sim,
            WIDE_EXPERTS,
            cfg.token_compute_us / n_layers as f64,
        )
        .unwrap()
    };
    let inputs: WorkloadInputs<WIDE> = WorkloadInputs {
        spec: &spec,
        schedule: &schedule,
        pools: &pools,
        fit_traces: &fit,
        learned: None,
        cfg: &cfg,
        sim: &sim,
        eam: &eam,
        n_layers,
        n_experts: WIDE_EXPERTS,
    };
    let a = run_workload(&inputs, PredictorKind::Eam, mk_mem()).unwrap();
    let b = run_workload(&inputs, PredictorKind::Eam, mk_mem()).unwrap();
    assert!(a.counters.completions > 0, "no request completed");
    assert_eq!(a.counters.idle_while_runnable, 0, "work conservation");
    assert!(a.aggregate.cache.hits + a.aggregate.cache.misses > 0);
    // identical inputs → bitwise-identical reports
    assert_eq!(a.counters.steps, b.counters.steps);
    assert_eq!(a.counters.completions, b.counters.completions);
    assert_stats_identical("wide workload", &a.aggregate.cache, &b.aggregate.cache);
    assert_eq!(a.virtual_secs.to_bits(), b.virtual_secs.to_bits());
}
