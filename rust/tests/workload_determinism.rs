//! Contract tests for the multi-tenant workload simulator: generator
//! determinism, scheduler-policy invariants (work conservation, FCFS
//! ordering, round-robin no-starvation, shortest-remaining preference),
//! and a flat-vs-tiered contention parity smoke in the style of
//! `cache_contract.rs` (matched per-access costs ⇒ bit-identical
//! outcomes).

use moe_beyond::config::{CacheConfig, EamConfig, SimConfig, TierConfig, WorkloadConfig};
use moe_beyond::memory::{self, ExpertMemory};
use moe_beyond::obs::{ObsSink, DEFAULT_RING_CAP};
use moe_beyond::predictor::TracePredictions;
use moe_beyond::sim::PredictorKind;
use moe_beyond::tier::TierSpec;
use moe_beyond::trace::{CompiledCorpus, PromptTrace};
use moe_beyond::util::Rng;
use moe_beyond::workload::{
    report_json, run_workload, run_workload_engine, synthetic_fit_pool, synthetic_pools,
    ArrivalEvent, Schedule, SchedEngine, SchedPolicy, TenantProfile, WorkloadInputs,
    WorkloadReport, WorkloadSpec,
};

const N_LAYERS: usize = 4;
const N_EXPERTS: usize = 64;

struct Fixture {
    spec: WorkloadSpec,
    pools: Vec<Vec<PromptTrace>>,
    fit: Vec<PromptTrace>,
    schedule: Schedule,
}

/// Overloaded 3-tenant fixture: offered load well above the engine's
/// drain rate so queueing and interleaving actually happen.
fn fixture(load: f64) -> Fixture {
    let spec = WorkloadSpec::example(3, 23, 6.0).with_load(load);
    let pools = synthetic_pools(&spec, 5, N_LAYERS as u16, N_EXPERTS);
    let fit = synthetic_fit_pool(&spec, 3, N_LAYERS as u16, N_EXPERTS);
    let schedule = spec.generate(&pools).unwrap();
    Fixture {
        spec,
        pools,
        fit,
        schedule,
    }
}

fn flat_memory(cap: usize, sim: &SimConfig, overlap_us: f64) -> Box<dyn ExpertMemory> {
    memory::build(
        "lru",
        &CacheConfig::default().with_capacity(cap),
        None,
        sim,
        N_EXPERTS,
        overlap_us,
    )
    .unwrap()
}

fn run(
    fx: &Fixture,
    policy: SchedPolicy,
    kind: PredictorKind,
    mem: Box<dyn ExpertMemory>,
) -> WorkloadReport {
    let cfg = WorkloadConfig {
        max_concurrency: 2,
        policy: policy.id().to_string(),
        ..Default::default()
    };
    let sim = SimConfig::default();
    let eam = EamConfig {
        kmeans_clusters: 0,
        ..Default::default()
    };
    let inputs = WorkloadInputs {
        spec: &fx.spec,
        schedule: &fx.schedule,
        pools: &fx.pools,
        fit_traces: &fx.fit,
        learned: None,
        cfg: &cfg,
        sim: &sim,
        eam: &eam,
        n_layers: N_LAYERS,
        n_experts: N_EXPERTS,
    };
    run_workload(&inputs, kind, mem).unwrap()
}

fn overlap_us() -> f64 {
    WorkloadConfig::default().token_compute_us / N_LAYERS as f64
}

#[test]
fn same_seed_same_schedule_and_report() {
    let a = fixture(2.0);
    let b = fixture(2.0);
    assert_eq!(a.schedule.arrivals.len(), b.schedule.arrivals.len());
    for (x, y) in a.schedule.arrivals.iter().zip(b.schedule.arrivals.iter()) {
        assert_eq!(x.arrival_us.to_bits(), y.arrival_us.to_bits());
        assert_eq!((x.tenant, x.trace_idx), (y.tenant, y.trace_idx));
        assert_eq!(
            (x.prompt_tokens, x.decode_tokens),
            (y.prompt_tokens, y.decode_tokens)
        );
    }
    let sim = SimConfig::default();
    let mem = || flat_memory(25, &sim, overlap_us());
    let ra = run(&a, SchedPolicy::RoundRobin, PredictorKind::Eam, mem());
    let rb = run(&b, SchedPolicy::RoundRobin, PredictorKind::Eam, mem());
    let ja = report_json(&ra).to_json_string();
    let jb = report_json(&rb).to_json_string();
    assert_eq!(ja, jb, "same seed produced different reports");
    // and the seed genuinely drives the numbers
    let c = {
        let mut f = fixture(2.0);
        f.spec.seed = 99;
        f.schedule = f.spec.generate(&f.pools).unwrap();
        f
    };
    let rc = run(&c, SchedPolicy::RoundRobin, PredictorKind::Eam, mem());
    assert_ne!(ja, report_json(&rc).to_json_string());
}

#[test]
fn work_conservation_and_counter_balance_across_policies() {
    let fx = fixture(3.0);
    let n = fx.schedule.arrivals.len() as u64;
    assert!(n >= 10, "overloaded fixture produced too few arrivals ({n})");
    let sim = SimConfig::default();
    for policy in SchedPolicy::ALL {
        let mem = flat_memory(25, &sim, overlap_us());
        let r = run(&fx, policy, PredictorKind::None, mem);
        let c = &r.counters;
        assert_eq!(c.admissions, n, "{policy:?}");
        assert_eq!(c.completions, n, "{policy:?}");
        assert_eq!(c.prefill_steps, n, "{policy:?}");
        assert_eq!(c.idle_while_runnable, 0, "{policy:?} idled while runnable");
        // busy + idle account for the whole virtual timeline
        let total = c.busy_us + c.idle_us;
        let clock = r.virtual_secs * 1e6;
        assert!(
            (total - clock).abs() <= 1e-6 * clock.max(1.0),
            "{policy:?}: busy {} + idle {} != clock {}",
            c.busy_us,
            c.idle_us,
            clock
        );
        // every decode (token, layer) revealed top_k = 2 experts
        let a = &r.aggregate;
        assert_eq!(c.steps, a.tokens);
        assert_eq!(a.cache.lookups(), a.tokens * N_LAYERS as u64 * 2);
        assert_eq!(a.ttft.count as u64, n);
        assert_eq!(a.request_latency.count as u64, n);
        assert_eq!(a.tbt.count as u64, a.tokens - n);
        // per-tenant counters fold exactly into the aggregate
        let sums: u64 = r.tenants.iter().map(|t| t.completed).sum();
        assert_eq!(sums, n);
        let hits: u64 = r.tenants.iter().map(|t| t.cache.hits).sum();
        assert_eq!(hits, a.cache.hits);
        // overload really queued requests and interleaved streams
        assert!(c.max_inflight >= 2, "{policy:?} never overlapped streams");
        assert!(c.max_queue_depth >= 1, "{policy:?} never queued");
    }
}

#[test]
fn round_robin_never_repeats_with_waiters() {
    let fx = fixture(3.0);
    let r = run(
        &fx,
        SchedPolicy::RoundRobin,
        PredictorKind::None,
        flat_memory(25, &SimConfig::default(), overlap_us()),
    );
    assert!(r.counters.max_inflight >= 2);
    assert_eq!(
        r.counters.repeat_pick_with_waiters, 0,
        "round-robin stepped the same stream twice while another waited"
    );
}

#[test]
fn fcfs_completes_in_arrival_order() {
    let fx = fixture(3.0);
    let r = run(
        &fx,
        SchedPolicy::Fcfs,
        PredictorKind::None,
        flat_memory(25, &SimConfig::default(), overlap_us()),
    );
    let ids = &r.completion_ids;
    assert_eq!(ids.len(), fx.schedule.arrivals.len());
    for w in ids.windows(2) {
        assert!(w[0] < w[1], "fcfs completed {} after {}", w[0], w[1]);
    }
}

/// Hand-built two-request schedule: a 20-token and a 2-token request
/// arrive together; shortest-remaining-decode must finish the short one
/// first, FCFS the first-arrived one.
#[test]
fn shortest_remaining_prefers_short_requests() {
    let tenant = TenantProfile {
        name: "t0".into(),
        arrival: moe_beyond::workload::ArrivalProcess::Poisson { rate_rps: 1.0 },
        prompt_tokens: (4, 4),
        decode_tokens: (2, 20),
        trace_seed: 5,
    };
    let spec = WorkloadSpec {
        seed: 5,
        horizon_secs: 1.0,
        tenants: vec![tenant],
    };
    let pools = synthetic_pools(&spec, 1, N_LAYERS as u16, N_EXPERTS);
    let mk = |id: u64, decode: usize| ArrivalEvent {
        arrival_us: 0.0,
        tenant: 0,
        request_id: id,
        trace_idx: 0,
        prompt_tokens: 4,
        decode_tokens: decode,
    };
    let schedule = Schedule {
        arrivals: vec![mk(0, 20), mk(1, 2)],
        horizon_us: 1e6,
        offered_rps: 2.0,
    };
    let fx = Fixture {
        spec,
        pools,
        fit: vec![],
        schedule,
    };
    let srd = run(
        &fx,
        SchedPolicy::ShortestRemaining,
        PredictorKind::None,
        flat_memory(25, &SimConfig::default(), overlap_us()),
    );
    assert_eq!(srd.completion_ids, vec![1, 0]);
    let fcfs = run(
        &fx,
        SchedPolicy::Fcfs,
        PredictorKind::None,
        flat_memory(25, &SimConfig::default(), overlap_us()),
    );
    assert_eq!(fcfs.completion_ids, vec![0, 1]);
}

/// The learned-predictor wiring adds a prediction SOURCE, not a
/// different engine: oracle-equivalent precomputed predictions (each
/// trace's own ground truth) must reproduce the Oracle run bit for bit,
/// and a learned run without predictions must fail loudly.
#[test]
fn learned_predictions_reproduce_oracle_run() {
    let fx = fixture(2.0);
    // per-pool TracePredictions whose sets ARE the ground truth — the
    // CachedPredictor then predicts exactly what OraclePredictor reads
    let preds: Vec<Vec<TracePredictions>> = fx
        .pools
        .iter()
        .map(|pool| {
            pool.iter()
                .map(|tr| TracePredictions {
                    n_layers: N_LAYERS,
                    sets: (0..tr.n_tokens())
                        .map(|t| (0..N_LAYERS).map(|l| tr.expert_set(t, l)).collect())
                        .collect(),
                    logits: vec![Vec::new(); tr.n_tokens()],
                    n_experts: N_EXPERTS,
                })
                .collect()
        })
        .collect();
    let cfg = WorkloadConfig {
        max_concurrency: 2,
        policy: "round-robin".into(),
        ..Default::default()
    };
    let sim = SimConfig::default();
    let eam = EamConfig {
        kmeans_clusters: 0,
        ..Default::default()
    };
    let oracle_inputs = WorkloadInputs {
        spec: &fx.spec,
        schedule: &fx.schedule,
        pools: &fx.pools,
        fit_traces: &fx.fit,
        learned: None,
        cfg: &cfg,
        sim: &sim,
        eam: &eam,
        n_layers: N_LAYERS,
        n_experts: N_EXPERTS,
    };
    let learned_inputs = WorkloadInputs {
        learned: Some(&preds),
        ..oracle_inputs
    };
    let oracle = run_workload(
        &oracle_inputs,
        PredictorKind::Oracle,
        flat_memory(25, &sim, overlap_us()),
    )
    .unwrap();
    let learned = run_workload(
        &learned_inputs,
        PredictorKind::Learned,
        flat_memory(25, &sim, overlap_us()),
    )
    .unwrap();
    assert_eq!(oracle.predictor, "oracle");
    assert_eq!(learned.predictor, "learned");
    assert_eq!(learned.completion_ids, oracle.completion_ids);
    assert_eq!(learned.counters.steps, oracle.counters.steps);
    assert_eq!(learned.counters.completions, oracle.counters.completions);
    assert_eq!(
        learned.virtual_secs.to_bits(),
        oracle.virtual_secs.to_bits(),
        "identical predictions must produce an identical virtual timeline"
    );
    let (la, oa) = (&learned.aggregate.cache, &oracle.aggregate.cache);
    assert_eq!(la.hits, oa.hits);
    assert_eq!(la.misses, oa.misses);
    assert_eq!(la.prefetches, oa.prefetches);
    assert_eq!(la.prediction_hits, oa.prediction_hits);
    assert_eq!(la.prediction_total, oa.prediction_total);
    assert_eq!(la.transfer_us.to_bits(), oa.transfer_us.to_bits());
    // ground-truth predictions are perfect predictions
    assert_eq!(la.prediction_hits, la.prediction_total);

    // learned without predictions is a configuration error, not a panic
    let err = run_workload(
        &oracle_inputs,
        PredictorKind::Learned,
        flat_memory(25, &sim, overlap_us()),
    );
    assert!(err.is_err(), "learned run without predictions must fail");
}

/// A tiered hierarchy whose GPU tier costs the flat hit cost and whose
/// full-size host tier costs exactly PCIe must reproduce the flat
/// backend bit for bit under multi-tenant contention — same per-tenant
/// hit/miss counters, same virtual timeline.
#[test]
fn flat_vs_tiered_contention_parity() {
    let fx = fixture(2.0);
    let sim = SimConfig::default();
    let cap = 25usize;
    let flat = run(
        &fx,
        SchedPolicy::RoundRobin,
        PredictorKind::Eam,
        flat_memory(cap, &sim, overlap_us()),
    );
    let cfg = CacheConfig::default();
    let tier_cfg = TierConfig {
        tiers: vec![
            // gpu fetch == flat hit_us, host fetch == flat pcie cost
            TierSpec::new("gpu", cap, cfg.hit_us, 0.0),
            TierSpec::new("host", N_LAYERS * N_EXPERTS, cfg.pcie_us_per_expert, 0.0),
        ],
        policy: "lru".into(),
    };
    let tiered_mem = memory::build(
        "lru",
        &cfg,
        Some(&tier_cfg),
        &sim,
        N_EXPERTS,
        overlap_us(),
    )
    .unwrap();
    let tiered = run(&fx, SchedPolicy::RoundRobin, PredictorKind::Eam, tiered_mem);

    assert_eq!(flat.backend, "flat");
    assert_eq!(tiered.backend, "tiered");
    for (f, t) in flat.tenants.iter().zip(tiered.tenants.iter()) {
        assert_eq!(f.cache.hits, t.cache.hits, "tenant {}", f.name);
        assert_eq!(f.cache.misses, t.cache.misses, "tenant {}", f.name);
        assert_eq!(f.cache.prefetches, t.cache.prefetches, "tenant {}", f.name);
        assert_eq!(
            f.cache.transfer_us.to_bits(),
            t.cache.transfer_us.to_bits(),
            "tenant {}",
            f.name
        );
    }
    assert_eq!(
        flat.virtual_secs.to_bits(),
        tiered.virtual_secs.to_bits(),
        "matched costs must produce an identical virtual timeline"
    );
    assert_eq!(
        flat.aggregate.ttft.p95_us.to_bits(),
        tiered.aggregate.ttft.p95_us.to_bits()
    );
    assert_eq!(
        flat.aggregate.tbt.p95_us.to_bits(),
        tiered.aggregate.tbt.p95_us.to_bits()
    );
    // the hierarchy did its work: deep tiers actually served lookups
    let ts = tiered.memory.tiers.as_ref().expect("tier stats");
    assert!(ts.served[1] > 0, "host tier never served under contention");
}

// ---- engine parity: the indexed runnable structures (calendar queue,
// admission ring, free-slot bitmap) against the linear-scan reference
// they replaced — byte-identical or bust.

/// Drain `fx` through one engine with a live trace ring; returns the
/// report plus the serialized Chrome trace.
fn run_engine(
    fx: &Fixture,
    policy: SchedPolicy,
    engine: SchedEngine,
    max_concurrency: usize,
) -> (WorkloadReport, String) {
    let cfg = WorkloadConfig {
        max_concurrency,
        policy: policy.id().to_string(),
        ..Default::default()
    };
    let sim = SimConfig::default();
    let eam = EamConfig {
        kmeans_clusters: 0,
        ..Default::default()
    };
    let inputs = WorkloadInputs {
        spec: &fx.spec,
        schedule: &fx.schedule,
        pools: &fx.pools,
        fit_traces: &fx.fit,
        learned: None,
        cfg: &cfg,
        sim: &sim,
        eam: &eam,
        n_layers: N_LAYERS,
        n_experts: N_EXPERTS,
    };
    let compiled: Vec<CompiledCorpus> =
        fx.pools.iter().map(|p| CompiledCorpus::compile(p)).collect();
    let obs = ObsSink::active(DEFAULT_RING_CAP, "virtual");
    let report = run_workload_engine(
        &inputs,
        PredictorKind::None,
        flat_memory(25, &sim, overlap_us()),
        &compiled,
        &obs,
        engine,
    )
    .unwrap();
    let trace = obs.trace_json().unwrap().to_json_string();
    (report, trace)
}

fn assert_engine_parity(fx: &Fixture, policy: SchedPolicy, max_concurrency: usize, what: &str) {
    let (ri, ti) = run_engine(fx, policy, SchedEngine::Indexed, max_concurrency);
    let (rl, tl) = run_engine(fx, policy, SchedEngine::LinearScan, max_concurrency);
    assert_eq!(
        report_json(&ri).to_json_string(),
        report_json(&rl).to_json_string(),
        "{what}: {policy:?}/mc={max_concurrency} reports diverged between engines"
    );
    assert_eq!(
        ri.completion_ids, rl.completion_ids,
        "{what}: {policy:?}/mc={max_concurrency} completion order diverged"
    );
    assert_eq!(
        ri.counters.out_of_order_completions, rl.counters.out_of_order_completions,
        "{what}: {policy:?}/mc={max_concurrency} order-violation counters diverged"
    );
    assert_eq!(
        ti, tl,
        "{what}: {policy:?}/mc={max_concurrency} Chrome traces diverged"
    );
}

#[test]
fn engines_are_byte_identical_on_the_generated_fixture() {
    let fx = fixture(3.0);
    for policy in SchedPolicy::ALL {
        for mc in [1usize, 2, 5] {
            assert_engine_parity(&fx, policy, mc, "generated fixture");
        }
    }
}

/// Randomized hand-built schedules (sorted arrivals, random tenants,
/// shapes clamped to each trace) hunt for pick-order divergence the
/// structured fixtures would never reach.
#[test]
fn engines_are_byte_identical_on_randomized_schedules() {
    for seed in [3u64, 71, 905] {
        let spec = WorkloadSpec::example(3, 23, 6.0);
        let pools = synthetic_pools(&spec, 5, N_LAYERS as u16, N_EXPERTS);
        let mut rng = Rng::new(seed);
        let n = 80 + rng.below(80);
        let mut times: Vec<f64> = (0..n).map(|_| rng.f64() * 4e6).collect();
        times.sort_by(f64::total_cmp);
        let arrivals: Vec<ArrivalEvent> = times
            .iter()
            .enumerate()
            .map(|(i, &arrival_us)| {
                let tenant = rng.below(pools.len());
                let trace_idx = rng.below(pools[tenant].len());
                let n_tok = pools[tenant][trace_idx].n_tokens();
                let prompt_tokens = 1 + rng.below(n_tok - 1);
                let decode_tokens = 1 + rng.below(n_tok - prompt_tokens);
                ArrivalEvent {
                    arrival_us,
                    tenant,
                    request_id: i as u64,
                    trace_idx,
                    prompt_tokens,
                    decode_tokens,
                }
            })
            .collect();
        let fx = Fixture {
            schedule: Schedule {
                arrivals,
                horizon_us: 6e6,
                offered_rps: n as f64 / 6.0,
            },
            spec,
            pools,
            fit: vec![],
        };
        for policy in SchedPolicy::ALL {
            for mc in [1usize, 3, 7] {
                assert_engine_parity(&fx, policy, mc, "randomized schedule");
            }
        }
    }
}

/// Round-robin cursor regression family: two streams admitted at t=0
/// wrap the cursor past the end of the admission ring, then two more
/// arrive together at a swept offset, interleaving admission with
/// completion at every cursor position the sweep reaches.  The
/// stable-slot cursor must match the reference index-shifting cursor
/// byte for byte at every offset.
#[test]
fn rr_cursor_wraparound_and_completion_interleave_parity() {
    let tenant = TenantProfile {
        name: "t0".into(),
        arrival: moe_beyond::workload::ArrivalProcess::Poisson { rate_rps: 1.0 },
        prompt_tokens: (4, 4),
        decode_tokens: (1, 8),
        trace_seed: 9,
    };
    let spec = WorkloadSpec {
        seed: 9,
        horizon_secs: 1.0,
        tenants: vec![tenant],
    };
    let pools = synthetic_pools(&spec, 1, N_LAYERS as u16, N_EXPERTS);
    let mk = |id: u64, at: f64, decode: usize| ArrivalEvent {
        arrival_us: at,
        tenant: 0,
        request_id: id,
        trace_idx: 0,
        prompt_tokens: 4,
        decode_tokens: decode,
    };
    for step in 0..50u32 {
        let off = f64::from(step) * 400.0;
        let fx = Fixture {
            spec: spec.clone(),
            pools: pools.clone(),
            fit: vec![],
            schedule: Schedule {
                arrivals: vec![mk(0, 0.0, 4), mk(1, 0.0, 2), mk(2, off, 3), mk(3, off, 1)],
                horizon_us: 1e6,
                offered_rps: 4.0,
            },
        };
        assert_engine_parity(&fx, SchedPolicy::RoundRobin, 3, "rr offset family");
    }
}

/// 10⁵ concurrent streams in one burst: the indexed engine admits them
/// all, round-robins fairly, conserves every counter, and caps the
/// completion log — the scale regime the calendar queue exists for.
#[test]
fn hundred_thousand_stream_burst_conserves_counters() {
    const STREAMS: usize = 100_000;
    let tenant = TenantProfile {
        name: "t0".into(),
        arrival: moe_beyond::workload::ArrivalProcess::Poisson { rate_rps: 1.0 },
        prompt_tokens: (1, 1),
        decode_tokens: (1, 2),
        trace_seed: 3,
    };
    let spec = WorkloadSpec {
        seed: 3,
        horizon_secs: 1.0,
        tenants: vec![tenant],
    };
    let n_layers = 2usize;
    let pools = synthetic_pools(&spec, 1, n_layers as u16, N_EXPERTS);
    let arrivals: Vec<ArrivalEvent> = (0..STREAMS)
        .map(|i| ArrivalEvent {
            arrival_us: 0.0,
            tenant: 0,
            request_id: i as u64,
            trace_idx: 0,
            prompt_tokens: 1,
            decode_tokens: 2,
        })
        .collect();
    let schedule = Schedule {
        arrivals,
        horizon_us: 1e6,
        offered_rps: STREAMS as f64,
    };
    let cfg = WorkloadConfig {
        max_concurrency: STREAMS,
        policy: "round-robin".into(),
        ..Default::default()
    };
    let sim = SimConfig::default();
    let eam = EamConfig {
        kmeans_clusters: 0,
        ..Default::default()
    };
    let inputs = WorkloadInputs {
        spec: &spec,
        schedule: &schedule,
        pools: &pools,
        fit_traces: &[],
        learned: None,
        cfg: &cfg,
        sim: &sim,
        eam: &eam,
        n_layers,
        n_experts: N_EXPERTS,
    };
    let mem = flat_memory(25, &sim, WorkloadConfig::default().token_compute_us / n_layers as f64);
    let r = run_workload(&inputs, PredictorKind::None, mem).unwrap();
    let c = &r.counters;
    assert_eq!(c.admissions, STREAMS as u64);
    assert_eq!(c.completions, STREAMS as u64);
    assert_eq!(c.prefill_steps, STREAMS as u64);
    assert_eq!(c.steps, 2 * STREAMS as u64);
    assert_eq!(c.max_inflight, STREAMS);
    assert_eq!(
        c.max_queue_depth, STREAMS,
        "burst depth must be sampled before admission drains it"
    );
    assert_eq!(c.idle_while_runnable, 0);
    assert_eq!(
        c.out_of_order_completions, 0,
        "equal-length round-robin completes in slot (= arrival) order"
    );
    assert_eq!(r.completion_ids.len(), cfg.completion_log_cap);
    assert_eq!(r.aggregate.tokens, 2 * STREAMS as u64);
    assert_eq!(r.aggregate.ttft.count as u64, STREAMS as u64);
}
